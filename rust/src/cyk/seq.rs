//! Sequential CYK oracle: the textbook `O(n³·|G|)` triangular fill.
//!
//! This is the tie-break reference for the pipeline executors
//! (DESIGN.md §8): per (span, nonterminal) slot, candidates arrive in
//! ascending `(split, rule index)` order and only a strictly greater
//! log-probability replaces the running best, so the recorded packed
//! `(split << 16) | rule` is always the *lowest* maximizing pair.  The
//! table uses the same MCM linear triangular layout as the pipeline
//! ([`linear::cell_index`]), `R` slots per span.

use crate::core::problem::CykProblem;
use crate::core::schedule::linear;
use crate::core::traceback::{cyk_parse, CykSolution};

/// Fill the triangular value table: `num_spans × R` log-probabilities,
/// diagonal from [`CykProblem::initial_table`], spans by ascending
/// length.
pub fn solve(p: &CykProblem) -> Vec<f64> {
    solve_with_splits(p).0
}

/// [`solve`] plus the packed `(split << 16) | rule` sidecar.  Slots never
/// written (unreachable nonterminals, and the whole diagonal) keep the
/// arena's zero initialization — bit-identical to the recorded sidecar of
/// the pipeline executors.
pub fn solve_with_splits(p: &CykProblem) -> (Vec<f64>, Vec<u32>) {
    let (n, r) = (p.n(), p.num_nonterminals);
    let mut st = p.initial_table();
    let mut splits = vec![0u32; st.len()];
    for d in 1..n {
        for i in 0..n - d {
            let j = i + d;
            let tgt = linear::cell_index(n, i, j) * r;
            for m in i..j {
                let left = linear::cell_index(n, i, m) * r;
                let right = linear::cell_index(n, m + 1, j) * r;
                for (ri, rule) in p.binary.iter().enumerate() {
                    let cand =
                        st[left + rule.rhs_b as usize] + st[right + rule.rhs_c as usize] + rule.logp;
                    let slot = tgt + rule.lhs as usize;
                    if cand > st[slot] {
                        st[slot] = cand;
                        splits[slot] = ((m as u32) << 16) | ri as u32;
                    }
                }
            }
        }
    }
    (st, splits)
}

/// Parse outright (oracle convenience for tests and the Python golden
/// harness).
pub fn parse(p: &CykProblem) -> CykSolution {
    let (st, splits) = solve_with_splits(p);
    cyk_parse(p, &st, &splits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::problem::CykRule;
    use crate::prop::forall;

    /// Exhaustive best-derivation search over all binary trees and all
    /// nonterminal labelings of a span — ground truth for small inputs.
    fn brute_best(p: &CykProblem, nt: usize, i: usize, j: usize) -> f64 {
        if i == j {
            return p.lexical_best(nt, p.words[i]);
        }
        let mut best = f64::NEG_INFINITY;
        for m in i..j {
            for rule in &p.binary {
                if rule.lhs as usize != nt {
                    continue;
                }
                let v = rule.logp
                    + brute_best(p, rule.rhs_b as usize, i, m)
                    + brute_best(p, rule.rhs_c as usize, m + 1, j);
                if v > best {
                    best = v;
                }
            }
        }
        best
    }

    #[test]
    fn dp_score_matches_brute_force() {
        forall("cyk seq == brute force", 40, |g| {
            // small n keeps the exponential brute force enumerable
            let p = CykProblem::random(g.rng(), 1..7, 4, 3);
            let sol = parse(&p);
            let want = brute_best(&p, 0, 0, p.n() - 1);
            let same = if want == f64::NEG_INFINITY {
                sol.score == f64::NEG_INFINITY && sol.tree.is_none()
            } else {
                (sol.score - want).abs() < 1e-9 && sol.tree.is_some()
            };
            if same {
                Ok(())
            } else {
                Err(format!("score {} != brute {want}: {p:?}", sol.score))
            }
        });
    }

    #[test]
    fn balanced_example_scores_catalan_uniform() {
        // S → S S | a, ln ½ each: any n-leaf tree scores (2n−1)·ln ½
        for n in 1..8usize {
            let p = CykProblem::balanced_example(n);
            let sol = parse(&p);
            let want = (2 * n - 1) as f64 * (0.5f64).ln();
            assert!(
                (sol.score - want).abs() < 1e-9,
                "n={n}: {} != {want}",
                sol.score
            );
        }
    }

    #[test]
    fn unparseable_sentence_is_neg_infinity() {
        // start symbol has no rules at all for a 2-word sentence
        let p = CykProblem::new(
            2,
            1,
            vec![CykRule {
                lhs: 1,
                rhs_b: 1,
                rhs_c: 1,
                logp: (0.5f64).ln(),
            }],
            vec![(1, 0, 0.0)],
            vec![0, 0],
        )
        .unwrap();
        let sol = parse(&p);
        assert_eq!(sol.score, f64::NEG_INFINITY);
        assert_eq!(sol.tree, None);
    }

    #[test]
    fn tie_breaks_pin_lowest_split_then_lowest_rule() {
        // two rules derive the same 2-word span with equal probability:
        // the recorded rule must be the lower-indexed one
        let half = (0.5f64).ln();
        let p = CykProblem::new(
            2,
            1,
            vec![
                CykRule { lhs: 0, rhs_b: 1, rhs_c: 1, logp: half },
                CykRule { lhs: 0, rhs_b: 1, rhs_c: 1, logp: half },
            ],
            vec![(1, 0, 0.0)],
            vec![0, 0],
        )
        .unwrap();
        let (_, splits) = solve_with_splits(&p);
        let root = linear::cell_index(2, 0, 1) * 2;
        assert_eq!(splits[root] >> 16, 0, "lowest split");
        assert_eq!(splits[root] & 0xFFFF, 0, "lowest rule index");
        assert_eq!(parse(&p).tree.as_deref(), Some("(N0 (N1 w0) (N1 w1))"));
    }
}
