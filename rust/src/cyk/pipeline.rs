//! The CYK pipeline: one `(max, ×)`-log-space kernel instantiating the
//! generic superstep sweep over the *cached corrected MCM schedule*
//! (DESIGN.md §11).
//!
//! Every arena term `(tgt, l, r, pb)` of the MCM schedule is one span
//! split; the kernel fans it out into `|binary rules|` candidates, each
//! a `⊗`-extension of the two child (span, nonterminal) slots with the
//! rule's log-probability, `⊕`-combined into the target slot by strict
//! improvement.  Hazard-freedom is inherited from the MCM certification
//! at span granularity: all `R` nonterminal slots of a span finalize
//! with the span, and a corrected schedule only reads spans finalized in
//! earlier supersteps ([`crate::core::certify::lower_cyk`]).
//!
//! Work assignment is by target span (`tgt % parties`), keeping every
//! slot's strict-improvement scan and its packed `(split << 16) | rule`
//! sidecar store on one party in arena order — the same single-writer
//! argument as MCM recording (DESIGN.md §8), and the reason recorded
//! sidecars are bit-identical to [`crate::cyk::seq::solve_with_splits`].

use crate::core::cache;
use crate::core::problem::{CykProblem, CykRule};
use crate::core::schedule::{default_mcm_tile, McmSchedule, McmVariant};
use crate::core::semiring::{LogMaxProb, Semiring};
use crate::core::sweep::{self, SharedSlice, SweepKernel};
use crate::core::traceback::{cyk_parse, CykSolution, NoRecord, SplitArena, SplitRecord};
use crate::runtime::exec_pool::{cancelled, CancelToken, ExecPool};

/// The CYK recurrence packaged for the generic sweep drivers.
struct CykKernel<'a, R: SplitRecord> {
    r: usize,
    rules: &'a [CykRule],
    sched: &'a McmSchedule,
    st: SharedSlice<f64>,
    ring: LogMaxProb,
    rec: R,
}

impl<'a, R: SplitRecord> CykKernel<'a, R> {
    fn new(p: &'a CykProblem, sched: &'a McmSchedule, st: &mut [f64], rec: R) -> Self {
        assert_eq!(p.n(), sched.n, "schedule/problem size mismatch");
        assert_eq!(
            sched.variant,
            McmVariant::Corrected,
            "cyk executes over the hazard-free Corrected schedule only"
        );
        debug_assert_eq!(st.len(), p.num_cells());
        CykKernel {
            r: p.num_nonterminals,
            rules: &p.binary,
            sched,
            st: SharedSlice::new(st.as_mut_ptr()),
            ring: LogMaxProb,
            rec,
        }
    }

    /// One schedule term = one span split: scan the binary rules in
    /// ascending index order, strict-improving each rule's target slot.
    ///
    /// # Safety
    /// `i < num_terms()`; the caller holds the sweep discipline — both
    /// child spans are finalized and the target span is accessed by no
    /// other party this superstep.
    #[inline(always)]
    unsafe fn term(&self, i: usize) {
        let sched = self.sched;
        // SAFETY: schedule cell indices are bounded by construction
        // (the same invariant MCM relies on) and scaled by the validated
        // `R = num_nonterminals`; rule nonterminals are `< R` by
        // `CykProblem::new`.  Table accesses are race-free by the
        // caller's contract.
        unsafe {
            let left = *sched.l.get_unchecked(i) as usize * self.r;
            let right = *sched.r.get_unchecked(i) as usize * self.r;
            let tgt = *sched.tgt.get_unchecked(i) as usize * self.r;
            // the MCM term at split m carries pb = m + 1
            let m = *sched.pb.get_unchecked(i) - 1;
            for (ri, rule) in self.rules.iter().enumerate() {
                let cand = self.ring.extend(
                    self.ring.extend(
                        self.st.read(left + rule.rhs_b as usize),
                        self.st.read(right + rule.rhs_c as usize),
                    ),
                    rule.logp,
                );
                let slot = tgt + rule.lhs as usize;
                if self.ring.improves(cand, self.st.read(slot)) {
                    self.st.write(slot, cand);
                    if R::ACTIVE {
                        self.rec.store(slot, (m << 16) | ri as u32);
                    }
                }
            }
        }
    }
}

impl<R: SplitRecord> SweepKernel for CykKernel<'_, R> {
    fn num_supersteps(&self) -> usize {
        self.sched.num_supersteps()
    }

    fn max_parties(&self) -> usize {
        self.sched.max_width().max(1)
    }

    unsafe fn superstep_party(&self, g: usize, party: usize, parties: usize) {
        // span ownership (`tgt % parties`): all splits of one span stay
        // on one party in arena order, so every (span, nonterminal)
        // slot's improvement chain and sidecar store is single-writer
        for i in self.sched.superstep_range(g) {
            // SAFETY: `i` is in the superstep CSR hence < num_terms;
            // child spans finalize in earlier supersteps (fusion-proof
            // tiling) and the target span is owned by this party.
            unsafe {
                if *self.sched.tgt.get_unchecked(i) as usize % parties != party {
                    continue;
                }
                self.term(i);
            }
        }
    }

    unsafe fn sweep_serial(&self) {
        // flat arena sweep, no superstep boundaries: hazard-freedom
        // makes each term's reads final wherever the cuts fall
        for i in 0..self.sched.num_terms() {
            // SAFETY: i < num_terms; serial discipline.
            unsafe { self.term(i) };
        }
    }
}

/// Fused single-threaded parse: fill the triangular table over a
/// compiled schedule, return the `num_spans × R` value table.
pub fn execute(p: &CykProblem, sched: &McmSchedule) -> Vec<f64> {
    let mut st = p.initial_table();
    sweep::run_fused(&CykKernel::new(p, sched, &mut st, NoRecord));
    st
}

/// [`execute`] + packed `(split << 16) | rule` recording (DESIGN.md §8).
pub fn execute_recorded(p: &CykProblem, sched: &McmSchedule) -> (Vec<f64>, Vec<u32>) {
    let mut st = p.initial_table();
    let splits = SplitArena::new(st.len());
    sweep::run_fused(&CykKernel::new(p, sched, &mut st, &splits));
    (st, splits.into_vec())
}

/// [`execute`] with cooperative cancellation: polls the [`CancelToken`]
/// every [`crate::runtime::exec_pool::CANCEL_POLL_STRIDE`] supersteps and
/// abandons the table with `Err(Timeout)` once it fires.
pub fn execute_cancellable(
    p: &CykProblem,
    sched: &McmSchedule,
    token: &CancelToken,
) -> crate::Result<Vec<f64>> {
    let mut st = p.initial_table();
    sweep::run_cancellable(&CykKernel::new(p, sched, &mut st, NoRecord), token)?;
    Ok(st)
}

/// Pooled parse: resident [`ExecPool`] workers sweep one superstep of
/// the schedule arena between barriers, spans split by `tgt % parties`.
pub fn execute_pooled(
    p: &CykProblem,
    sched: &McmSchedule,
    pool: &ExecPool,
    threads: usize,
) -> Vec<f64> {
    execute_pooled_counted(p, sched, pool, threads).0
}

/// [`execute_pooled`] + the number of barrier rounds it cost.
pub fn execute_pooled_counted(
    p: &CykProblem,
    sched: &McmSchedule,
    pool: &ExecPool,
    threads: usize,
) -> (Vec<f64>, u64) {
    let mut st = p.initial_table();
    let rounds =
        sweep::run_pooled_counted(&CykKernel::new(p, sched, &mut st, NoRecord), pool, threads);
    (st, rounds)
}

/// [`execute_pooled`] with cooperative cancellation via the superstep cut
/// protocol (see [`sweep::run_pooled_cancellable_counted`]).
pub fn execute_pooled_cancellable(
    p: &CykProblem,
    sched: &McmSchedule,
    pool: &ExecPool,
    threads: usize,
    token: &CancelToken,
) -> crate::Result<Vec<f64>> {
    execute_pooled_cancellable_counted(p, sched, pool, threads, token).0
}

/// [`execute_pooled_cancellable`] + the barrier rounds it cost.
pub fn execute_pooled_cancellable_counted(
    p: &CykProblem,
    sched: &McmSchedule,
    pool: &ExecPool,
    threads: usize,
    token: &CancelToken,
) -> (crate::Result<Vec<f64>>, u64) {
    if token.is_never() {
        let (st, rounds) = execute_pooled_counted(p, sched, pool, threads);
        return (Ok(st), rounds);
    }
    if token.is_cancelled() {
        return (cancelled(), 0);
    }
    let mut st = p.initial_table();
    let (r, rounds) = sweep::run_pooled_cancellable_counted(
        &CykKernel::new(p, sched, &mut st, NoRecord),
        pool,
        threads,
        token,
    );
    (r.map(|()| st), rounds)
}

/// [`execute_pooled`] + sidecar recording: span ownership keeps each slot
/// single-writer (DESIGN.md §8).
pub fn execute_pooled_recorded(
    p: &CykProblem,
    sched: &McmSchedule,
    pool: &ExecPool,
    threads: usize,
) -> (Vec<f64>, Vec<u32>) {
    let mut st = p.initial_table();
    let splits = SplitArena::new(st.len());
    sweep::run_pooled_counted(&CykKernel::new(p, sched, &mut st, &splits), pool, threads);
    (st, splits.into_vec())
}

/// Convenience: fused parse over the cached untiled CYK schedule.
pub fn solve(p: &CykProblem) -> Vec<f64> {
    let sched = cache::cyk_schedule(p.n(), 1);
    execute(p, &sched)
}

/// Convenience: recorded fused parse over the cached untiled schedule —
/// the router's fused `want_solution` route.
pub fn solve_recorded(p: &CykProblem) -> (Vec<f64>, Vec<u32>) {
    let sched = cache::cyk_schedule(p.n(), 1);
    execute_recorded(p, &sched)
}

/// Parse end to end: recorded fused solve + derivation rebuild.
pub fn solve_parsed(p: &CykProblem) -> CykSolution {
    let (st, splits) = solve_recorded(p);
    cyk_parse(p, &st, &splits)
}

/// Parse end to end on the process-wide pool — the router's pooled
/// `want_solution` route.
pub fn solve_pooled_parsed(p: &CykProblem) -> CykSolution {
    let n = p.n();
    let sched = cache::cyk_schedule(n, default_mcm_tile(n));
    let pool = crate::runtime::exec_pool::global();
    let (st, splits) = execute_pooled_recorded(p, &sched, pool, pool.threads());
    cyk_parse(p, &st, &splits)
}

/// Convenience: pooled parse on the process-wide pool with the cached
/// default-tiled schedule.
pub fn solve_pooled(p: &CykProblem) -> Vec<f64> {
    let n = p.n();
    let sched = cache::cyk_schedule(n, default_mcm_tile(n));
    let pool = crate::runtime::exec_pool::global();
    execute_pooled(p, &sched, pool, pool.threads())
}

/// Convenience: cancellable pooled parse on the process-wide pool.
pub fn solve_pooled_cancellable(p: &CykProblem, token: &CancelToken) -> crate::Result<Vec<f64>> {
    let n = p.n();
    let sched = cache::cyk_schedule(n, default_mcm_tile(n));
    let pool = crate::runtime::exec_pool::global();
    execute_pooled_cancellable(p, &sched, pool, pool.threads(), token)
}

/// Lane-batched single-threaded parse (ISSUE 9 tentpole, DESIGN.md §12):
/// dual *per-nonterminal* row-/column-major span tables make each split
/// scan's left operands (`(i, m)` for `m ∈ [i, j)`) and right operands
/// (`(m+1, j)`) contiguous, so one
/// [`crate::core::simd::max_plus_argmax_bias`] call per (cell, rule)
/// replaces the rule-major scalar scan.  No schedule is compiled or
/// cached — the span loop *is* the wavefront.
///
/// Bit-identity with [`seq::solve_with_splits`] (strict `(split, rule)`
/// lex first-wins): per rule the batched argmax keeps the lowest split
/// attaining the rule's max (strict per-lane improvement + lowest-index
/// horizontal reduction), and the cross-rule merge in ascending rule
/// order replaces only on a strictly greater value *or* an equal value
/// at a strictly lower split — so the surviving candidate is exactly
/// the `(m, ri)`-lex-least maximizer, and its bit pattern (think
/// `-0.0` vs `+0.0`, which compare equal) is the one the scalar scan
/// keeps.  `⊕` over `f64` is order-insensitive here because no operand
/// is NaN (log-probs are finite, tables hold finite values or `−∞`).
pub fn solve_simd(p: &CykProblem) -> Vec<f64> {
    // infallible without a token
    match simd_sweep(p, NoRecord, None) {
        Ok(st) => st,
        Err(_) => unreachable!("no token, no cancellation"),
    }
}

/// [`solve_simd`] + packed `(split << 16) | rule` recording — bit
/// identical to the seq oracle's sidecar (see [`solve_simd`] docs).
pub fn solve_simd_recorded(p: &CykProblem) -> (Vec<f64>, Vec<u32>) {
    let splits = SplitArena::new(p.num_cells());
    match simd_sweep(p, &splits, None) {
        Ok(st) => (st, splits.into_vec()),
        Err(_) => unreachable!("no token, no cancellation"),
    }
}

/// Parse end to end through the lane-batched kernel — the router's
/// `simd` `want_solution` route.
pub fn solve_simd_parsed(p: &CykProblem) -> CykSolution {
    let (st, splits) = solve_simd_recorded(p);
    cyk_parse(p, &st, &splits)
}

/// [`solve_simd`] with cooperative cancellation, polling once per
/// [`crate::runtime::exec_pool::CANCEL_POLL_STRIDE`] span lengths.
pub fn solve_simd_cancellable(p: &CykProblem, token: &CancelToken) -> crate::Result<Vec<f64>> {
    if token.is_never() {
        return Ok(solve_simd(p));
    }
    if token.is_cancelled() {
        return cancelled();
    }
    simd_sweep(p, NoRecord, Some(token))
}

/// The dual-table lane-batched CYK fill shared by the `solve_simd*`
/// tiers.  `trow[(nt·n + i)·n + j]` and `tcol[(nt·n + j)·n + i]` hold
/// span `(i, j)`'s slot for nonterminal `nt` in row- and column-major
/// order; both are written at cell completion so later spans always
/// find their operands contiguous.  The result is converted to the
/// canonical linear triangular layout at the end.
fn simd_sweep<R: SplitRecord>(
    p: &CykProblem,
    rec: R,
    token: Option<&CancelToken>,
) -> crate::Result<Vec<f64>> {
    use crate::core::simd;
    use crate::runtime::exec_pool::CANCEL_POLL_STRIDE;

    let (n, r) = (p.n(), p.num_nonterminals);
    let mut st = p.initial_table();
    if n <= 1 || p.binary.is_empty() {
        return Ok(st);
    }
    let stride = n * n;
    let mut trow = vec![f64::NEG_INFINITY; r * stride];
    let mut tcol = vec![f64::NEG_INFINITY; r * stride];
    for i in 0..n {
        let cell = crate::core::schedule::linear::cell_index(n, i, i) * r;
        for nt in 0..r {
            trow[nt * stride + i * n + i] = st[cell + nt];
            tcol[nt * stride + i * n + i] = st[cell + nt];
        }
    }
    // per-lhs merge state, reset per cell (r is small)
    let mut best = vec![f64::NEG_INFINITY; r];
    let mut best_m = vec![0usize; r];
    let mut has = vec![false; r];
    for d in 1..n {
        if let Some(tok) = token {
            if d % CANCEL_POLL_STRIDE == 0 && tok.is_cancelled() {
                return cancelled();
            }
        }
        for i in 0..n - d {
            let j = i + d;
            for lhs in 0..r {
                best[lhs] = f64::NEG_INFINITY;
                has[lhs] = false;
            }
            for (ri, rule) in p.binary.iter().enumerate() {
                let b = rule.rhs_b as usize;
                let c = rule.rhs_c as usize;
                let left = &trow[b * stride + i * n + i..b * stride + i * n + j];
                let right = &tcol[c * stride + j * n + i + 1..c * stride + j * n + j + 1];
                let (val, arg) = simd::max_plus_argmax_bias(left, right, rule.logp);
                if val == f64::NEG_INFINITY {
                    continue; // the scalar scan never improves on −∞
                }
                let m = i + arg as usize;
                let lhs = rule.lhs as usize;
                if !has[lhs] || val > best[lhs] || (val == best[lhs] && m < best_m[lhs]) {
                    // keep `val`'s own bit pattern (−0.0 vs +0.0 ties)
                    best[lhs] = val;
                    best_m[lhs] = m;
                    has[lhs] = true;
                    if R::ACTIVE {
                        rec.store(
                            crate::core::schedule::linear::cell_index(n, i, j) * r + lhs,
                            ((m as u32) << 16) | ri as u32,
                        );
                    }
                }
            }
            for lhs in 0..r {
                if has[lhs] {
                    trow[lhs * stride + i * n + j] = best[lhs];
                    tcol[lhs * stride + j * n + i] = best[lhs];
                }
            }
        }
    }
    for d in 1..n {
        for i in 0..n - d {
            let j = i + d;
            let cell = crate::core::schedule::linear::cell_index(n, i, j) * r;
            for nt in 0..r {
                st[cell + nt] = trow[nt * stride + i * n + j];
            }
        }
    }
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cyk::seq;
    use crate::prop::forall;

    #[test]
    fn all_tiers_bit_identical_to_seq_oracle() {
        let pool = ExecPool::new(8);
        forall("cyk tiers == seq", 25, |g| {
            let p = CykProblem::random(g.rng(), 1..14, 4, 3);
            let n = p.n();
            let (want_st, want_sp) = seq::solve_with_splits(&p);
            let sched = McmSchedule::compile(n, McmVariant::Corrected);
            let fused = execute(&p, &sched);
            let (rst, rsp) = execute_recorded(&p, &sched);
            if fused != want_st || rst != want_st || rsp != want_sp {
                return Err(format!("fused diverged: {p:?}"));
            }
            for threads in [1usize, 2, 8] {
                let tile = *g.choose(&[1usize, 4, 64]);
                let tsched = McmSchedule::compile_tiled(n, McmVariant::Corrected, tile);
                let pooled = execute_pooled(&p, &tsched, &pool, threads);
                let (pst, psp) = execute_pooled_recorded(&p, &tsched, &pool, threads);
                if pooled != want_st || pst != want_st || psp != want_sp {
                    return Err(format!("pooled(t={threads},T={tile}) diverged: {p:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn simd_matches_seq_oracle_bit_for_bit_including_splits() {
        // ISSUE 9 satellite (c): the lane-batched dual-table kernel must
        // reproduce the scalar `(split, rule)` lex tie-break exactly —
        // scores AND the packed sidecar, across non-multiple-of-LANES
        // span counts and rule sets
        forall("cyk simd == seq", 30, |g| {
            let p = CykProblem::random(g.rng(), 1..20, 5, 4);
            let (want_st, want_sp) = seq::solve_with_splits(&p);
            if solve_simd(&p) != want_st {
                return Err(format!("simd table diverged: {p:?}"));
            }
            let (st, sp) = solve_simd_recorded(&p);
            if st != want_st || sp != want_sp {
                return Err(format!("simd recorded diverged: {p:?}"));
            }
            if solve_simd_parsed(&p) != seq::parse(&p) {
                return Err(format!("simd parse diverged: {p:?}"));
            }
            let live = CancelToken::after(std::time::Duration::from_secs(600));
            if solve_simd_cancellable(&p, &CancelToken::never()).unwrap() != want_st
                || solve_simd_cancellable(&p, &live).unwrap() != want_st
            {
                return Err(format!("simd cancellable diverged: {p:?}"));
            }
            let expired = CancelToken::at(std::time::Instant::now());
            if !matches!(
                solve_simd_cancellable(&p, &expired),
                Err(crate::Error::Timeout(_))
            ) {
                return Err("expired token must cancel the simd sweep".into());
            }
            Ok(())
        });
    }

    #[test]
    fn parsed_solution_matches_seq_parse() {
        forall("cyk parse == seq parse", 25, |g| {
            let p = CykProblem::random(g.rng(), 1..12, 4, 3);
            let a = solve_parsed(&p);
            let b = seq::parse(&p);
            let c = solve_pooled_parsed(&p);
            if a == b && a == c {
                Ok(())
            } else {
                Err(format!("{a:?} vs {b:?} vs {c:?}: {p:?}"))
            }
        });
    }

    #[test]
    fn balanced_example_parses_through_the_pooled_route() {
        let p = CykProblem::balanced_example(3);
        let sol = solve_pooled_parsed(&p);
        assert_eq!(sol.tree.as_deref(), Some("(N0 (N0 w0) (N0 (N0 w1) (N0 w2)))"));
        assert!((sol.score - 5.0 * (0.5f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn cancellable_with_never_or_live_token_matches_oracle() {
        let pool = ExecPool::new(4);
        forall("cyk cancellable == seq", 15, |g| {
            let p = CykProblem::random(g.rng(), 1..12, 4, 3);
            let n = p.n();
            let want = seq::solve(&p);
            let sched = McmSchedule::compile(n, McmVariant::Corrected);
            let tsched = McmSchedule::compile_tiled(n, McmVariant::Corrected, 4);
            let live = CancelToken::after(std::time::Duration::from_secs(600));
            let a = execute_cancellable(&p, &sched, &CancelToken::never()).unwrap();
            let b = execute_cancellable(&p, &sched, &live).unwrap();
            let c = execute_pooled_cancellable(&p, &tsched, &pool, 4, &live).unwrap();
            if a == want && b == want && c == want {
                Ok(())
            } else {
                Err(format!("{p:?}"))
            }
        });
    }

    #[test]
    fn expired_deadline_never_engages_the_pool() {
        let pool = ExecPool::new(4);
        let mut rng = crate::util::rng::Rng::seeded(23);
        let p = CykProblem::random(&mut rng, 9..10, 4, 3);
        let sched = McmSchedule::compile_tiled(p.n(), McmVariant::Corrected, 2);
        let expired = CancelToken::at(std::time::Instant::now());
        let before = pool.stats().solves;
        let (r, rounds) = execute_pooled_cancellable_counted(&p, &sched, &pool, 4, &expired);
        assert!(matches!(r, Err(crate::Error::Timeout(_))));
        assert_eq!(rounds, 0);
        assert_eq!(pool.stats().solves, before);
        // pool still serves afterwards
        assert_eq!(execute_pooled(&p, &sched, &pool, 4), seq::solve(&p));
    }

    #[test]
    fn pooled_superstep_barrier_budget_matches_the_schedule() {
        // the sync amortization the MCM schedule already certifies must
        // carry over to its CYK reuse: exactly num_supersteps barriers
        let pool = ExecPool::new(3);
        let mut rng = crate::util::rng::Rng::seeded(7);
        for (n, tile) in [(9usize, 2usize), (14, 4), (11, 3)] {
            let p = CykProblem::random(&mut rng, n..n + 1, 4, 3);
            let sched = McmSchedule::compile_tiled(n, McmVariant::Corrected, tile);
            let (st, rounds) = execute_pooled_counted(&p, &sched, &pool, 3);
            assert_eq!(st, seq::solve(&p), "n={n} tile={tile}");
            assert_eq!(rounds as usize, sched.num_supersteps(), "n={n} tile={tile}");
            assert!((rounds as usize) < sched.num_steps());
        }
    }

    #[test]
    fn solve_pooled_uses_the_cyk_schedule_cache() {
        let p = CykProblem::balanced_example(12);
        let a = solve_pooled(&p);
        let before = crate::core::cache::global_stats().hits;
        let b = solve_pooled(&p);
        assert_eq!(a, b);
        assert!(
            crate::core::cache::global_stats().hits > before,
            "second pooled parse must hit the schedule cache"
        );
    }

    #[test]
    #[should_panic(expected = "Corrected")]
    fn kernel_rejects_faithful_schedules() {
        let p = CykProblem::balanced_example(6);
        let sched = McmSchedule::compile(6, McmVariant::PaperFaithful);
        execute(&p, &sched);
    }
}
