//! Minimal property-based testing framework (proptest is unavailable
//! offline).
//!
//! A property is a closure from a seeded [`Gen`] to `Result<(), String>`;
//! the runner executes it for a configurable number of cases with
//! deterministic per-case seeds, and on failure reports the failing seed so
//! a case can be replayed exactly:
//!
//! ```
//! use pipedp::prop::{forall, Gen};
//! forall("addition commutes", 100, |g: &mut Gen| {
//!     let a = g.i64(-1000..1000);
//!     let b = g.i64(-1000..1000);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case value generator (a thin layer over [`Rng`] with domain-specific
/// draws used across the suite).
pub struct Gen {
    rng: Rng,
    /// Human-readable log of drawn values, included in failure reports.
    log: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::seeded(seed),
            log: Vec::new(),
        }
    }

    fn note(&mut self, label: &str, v: impl std::fmt::Debug) {
        if self.log.len() < 64 {
            self.log.push(format!("{label}={v:?}"));
        }
    }

    pub fn i64(&mut self, range: std::ops::Range<i64>) -> i64 {
        let v = self.rng.range(range);
        self.note("i64", v);
        v
    }

    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        let v = self.rng.range(range.start as i64..range.end as i64) as usize;
        self.note("usize", v);
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.note("bool", v);
        v
    }

    pub fn f64(&mut self) -> f64 {
        let v = self.rng.f64();
        self.note("f64", v);
        v
    }

    /// A vector of i64 values.
    pub fn vec_i64(&mut self, len: usize, range: std::ops::Range<i64>) -> Vec<i64> {
        let v: Vec<i64> = (0..len).map(|_| self.rng.range(range.clone())).collect();
        self.note("vec", &v);
        v
    }

    /// A valid S-DP offset vector: k distinct decreasing values in [1, max].
    pub fn offsets(&mut self, k: usize, max: i64) -> Vec<i64> {
        let v = self.rng.offsets(k, max);
        self.note("offsets", &v);
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// Matrix-chain dims vector of n+1 entries in [1, max_dim].
    pub fn dims(&mut self, n: usize, max_dim: i64) -> Vec<i64> {
        let v: Vec<i64> = (0..=n).map(|_| self.rng.range(1..max_dim + 1)).collect();
        self.note("dims", &v);
        v
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` instances of a property; panic with seed + draw log on the
/// first failure.  Seeds are derived deterministically from the property
/// name so failures reproduce across runs and machines.
pub fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}): {msg}\n  draws: [{}]",
                g.log.join(", ")
            );
        }
    }
}

/// Replay a single failing case by seed (debugging helper).
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) -> Result<(), String> {
    prop(&mut Gen::new(seed))
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("always ok", 50, |_g| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_context() {
        forall("always fails", 10, |g| {
            let v = g.i64(0..10);
            Err(format!("saw {v}"))
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<i64> = Vec::new();
        forall("det", 20, |g| {
            first.push(g.i64(0..1_000_000));
            Ok(())
        });
        let mut second: Vec<i64> = Vec::new();
        forall("det", 20, |g| {
            second.push(g.i64(0..1_000_000));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn offsets_valid() {
        forall("offsets valid", 100, |g| {
            let k = g.usize(1..9);
            let max = (k as i64) + g.i64(0..30);
            let offs = g.offsets(k, max);
            if offs.windows(2).all(|w| w[0] > w[1]) && offs[offs.len() - 1] >= 1 {
                Ok(())
            } else {
                Err(format!("{offs:?}"))
            }
        });
    }

    #[test]
    fn replay_reproduces() {
        let mut a = Gen::new(99);
        let x = a.i64(0..1000);
        let r = replay(99, |g| {
            let y = g.i64(0..1000);
            if y == x {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
        assert!(r.is_ok());
    }
}
