//! The Viterbi lattice pipeline: one `(max, ×)`-log-space kernel
//! ([`crate::core::semiring::LogMaxProb`]) instantiating the generic
//! superstep sweep (DESIGN.md §11).
//!
//! The schedule is implicit and trivially hazard-free — superstep `g`
//! computes lattice column `t = g + 1` from column `t − 1` only, so no
//! arena is compiled and nothing is certified beyond the lowered IR of
//! [`crate::core::certify::lower_viterbi`].  Work assignment is by state
//! (`s % parties`), keeping each cell's max-scan and its backpointer
//! store on one party.  `R = NoRecord` compiles the plain decode;
//! `R = &SplitArena` additionally records the argmax predecessor of
//! every cell under the pinned lowest-index tie-break — bit-identical
//! to [`crate::viterbi::seq::solve_with_backpointers`].

use crate::core::problem::ViterbiProblem;
use crate::core::semiring::{LogMaxProb, Semiring};
use crate::core::simd;
use crate::core::sweep::{self, SharedSlice, SweepKernel};
use crate::core::traceback::{viterbi_path, NoRecord, SplitArena, SplitRecord, ViterbiSolution};
use crate::runtime::exec_pool::{cancelled, CancelToken, ExecPool};

/// The Viterbi recurrence packaged for the generic sweep drivers.
struct ViterbiKernel<'a, R: SplitRecord> {
    s: usize,
    m: usize,
    trans: &'a [f64],
    emit: &'a [f64],
    obs: &'a [usize],
    st: SharedSlice<f64>,
    ring: LogMaxProb,
    rec: R,
}

impl<'a, R: SplitRecord> ViterbiKernel<'a, R> {
    fn new(p: &'a ViterbiProblem, st: &mut [f64], rec: R) -> Self {
        debug_assert_eq!(st.len(), p.num_cells());
        ViterbiKernel {
            s: p.num_states,
            m: p.num_symbols,
            trans: &p.trans,
            emit: &p.emit,
            obs: &p.obs,
            st: SharedSlice::new(st.as_mut_ptr()),
            ring: LogMaxProb,
            rec,
        }
    }

    /// One lattice cell: scan all predecessors of state `j` at time `t`
    /// in ascending order, keep the strictly-best, `⊗`-extend with the
    /// emission, record the argmax.
    ///
    /// # Safety
    /// `1 ≤ t < T`, `j < S`; the caller holds the sweep discipline —
    /// column `t − 1` is finalized and cell `(t, j)` is accessed by no
    /// other party this superstep.
    #[inline(always)]
    unsafe fn cell(&self, t: usize, j: usize) {
        // SAFETY: all lattice/trans/emit/obs indices are bounded by the
        // problem's validated shapes (`trans[S²]`, `emit[S·M]`,
        // `obs[t] < M`); table accesses are race-free by the caller's
        // contract.
        unsafe {
            let mut best = self.ring.zero();
            let mut arg = 0u32;
            for q in 0..self.s {
                let cand = self.ring.extend(
                    self.st.read((t - 1) * self.s + q),
                    *self.trans.get_unchecked(q * self.s + j),
                );
                if self.ring.improves(cand, best) {
                    best = cand;
                    arg = q as u32;
                }
            }
            let idx = t * self.s + j;
            let emit = *self
                .emit
                .get_unchecked(j * self.m + *self.obs.get_unchecked(t));
            self.st.write(idx, self.ring.extend(best, emit));
            if R::ACTIVE {
                self.rec.store(idx, arg);
            }
        }
    }
}

impl<R: SplitRecord> SweepKernel for ViterbiKernel<'_, R> {
    fn num_supersteps(&self) -> usize {
        self.obs.len().saturating_sub(1)
    }

    fn max_parties(&self) -> usize {
        self.s
    }

    unsafe fn superstep_party(&self, g: usize, party: usize, parties: usize) {
        let t = g + 1;
        for j in 0..self.s {
            if j % parties != party {
                continue;
            }
            // SAFETY: column t−1 finalized in superstep g−1 (or is the
            // initial column); state ownership j % parties makes the
            // write and the sidecar store exclusive to this party.
            unsafe { self.cell(t, j) };
        }
    }
}

/// Fused single-threaded decode: fill the lattice, return the table.
pub fn execute(p: &ViterbiProblem) -> Vec<f64> {
    let mut st = p.initial_table();
    sweep::run_fused(&ViterbiKernel::new(p, &mut st, NoRecord));
    st
}

/// [`execute`] + backpointer recording (DESIGN.md §8): returns the solved
/// lattice and the per-cell argmax-predecessor sidecar.
pub fn execute_recorded(p: &ViterbiProblem) -> (Vec<f64>, Vec<u32>) {
    let mut st = p.initial_table();
    let bp = SplitArena::new(st.len());
    sweep::run_fused(&ViterbiKernel::new(p, &mut st, &bp));
    (st, bp.into_vec())
}

/// Column-batched vectorized decode (DESIGN.md §12) — the adaptive
/// policy's `simd` route.
///
/// The scalar kernel scans `trans[q·S + j]` with stride `S` per cell.
/// This path transposes the transition matrix once per solve
/// (`trans_t[j·S + q]`), making every cell one lane-batched `(max, +)`
/// argmax over two contiguous strips — the previous column and state
/// `j`'s incoming log-probabilities — via
/// [`crate::core::simd::max_plus_argmax`], whose strict-improvement
/// first-wins reduction is the same pinned lowest-predecessor tie-break
/// as [`ViterbiKernel::cell`].  The emission `⊗`-extend is applied
/// after the reduction, exactly as in the scalar kernel, so lattices
/// and backpointer sidecars stay bit-identical (including `-0.0` and
/// `-inf` propagation) to [`crate::viterbi::seq::solve_with_backpointers`].
pub fn execute_simd(p: &ViterbiProblem) -> Vec<f64> {
    let mut st = p.initial_table();
    simd_fill(p, &mut st, NoRecord);
    st
}

/// [`execute_simd`] + backpointer recording (DESIGN.md §8).
pub fn execute_simd_recorded(p: &ViterbiProblem) -> (Vec<f64>, Vec<u32>) {
    let mut st = p.initial_table();
    let bp = SplitArena::new(st.len());
    simd_fill(p, &mut st, &bp);
    (st, bp.into_vec())
}

/// Decode end to end over the vectorized column kernel — the router's
/// `simd` `want_solution` route.
pub fn solve_simd_decoded(p: &ViterbiProblem) -> ViterbiSolution {
    let (st, bp) = execute_simd_recorded(p);
    viterbi_path(p.num_states, &st, &bp)
}

/// The transposed column sweep behind the `execute_simd` family.
fn simd_fill<R: SplitRecord>(p: &ViterbiProblem, st: &mut [f64], rec: R) {
    let (s, m) = (p.num_states, p.num_symbols);
    if p.obs.len() <= 1 {
        return;
    }
    // transpose once: state j's predecessors become one contiguous strip
    let mut trans_t = vec![0f64; s * s];
    for q in 0..s {
        for j in 0..s {
            trans_t[j * s + q] = p.trans[q * s + j];
        }
    }
    for t in 1..p.obs.len() {
        let (done, cur) = st.split_at_mut(t * s);
        let prev = &done[(t - 1) * s..];
        for (j, cell) in cur[..s].iter_mut().enumerate() {
            let (best, arg) = simd::max_plus_argmax(prev, &trans_t[j * s..(j + 1) * s]);
            *cell = best + p.emit[j * m + p.obs[t]];
            if R::ACTIVE {
                rec.store(t * s + j, arg);
            }
        }
    }
}

/// [`execute`] with cooperative cancellation: polls the [`CancelToken`]
/// every [`crate::runtime::exec_pool::CANCEL_POLL_STRIDE`] supersteps and
/// abandons the lattice with `Err(Timeout)` once it fires.
pub fn execute_cancellable(p: &ViterbiProblem, token: &CancelToken) -> crate::Result<Vec<f64>> {
    let mut st = p.initial_table();
    sweep::run_cancellable(&ViterbiKernel::new(p, &mut st, NoRecord), token)?;
    Ok(st)
}

/// Pooled decode: resident [`ExecPool`] workers sweep one lattice column
/// between barriers, states split by `j % parties`.
pub fn execute_pooled(p: &ViterbiProblem, pool: &ExecPool, threads: usize) -> Vec<f64> {
    execute_pooled_counted(p, pool, threads).0
}

/// [`execute_pooled`] + the number of barrier rounds it cost.
pub fn execute_pooled_counted(
    p: &ViterbiProblem,
    pool: &ExecPool,
    threads: usize,
) -> (Vec<f64>, u64) {
    let mut st = p.initial_table();
    let rounds = sweep::run_pooled_counted(&ViterbiKernel::new(p, &mut st, NoRecord), pool, threads);
    (st, rounds)
}

/// [`execute_pooled`] with cooperative cancellation via the superstep cut
/// protocol (see [`sweep::run_pooled_cancellable_counted`]).
pub fn execute_pooled_cancellable(
    p: &ViterbiProblem,
    pool: &ExecPool,
    threads: usize,
    token: &CancelToken,
) -> crate::Result<Vec<f64>> {
    execute_pooled_cancellable_counted(p, pool, threads, token).0
}

/// [`execute_pooled_cancellable`] + the barrier rounds it cost.
pub fn execute_pooled_cancellable_counted(
    p: &ViterbiProblem,
    pool: &ExecPool,
    threads: usize,
    token: &CancelToken,
) -> (crate::Result<Vec<f64>>, u64) {
    if token.is_never() {
        let (st, rounds) = execute_pooled_counted(p, pool, threads);
        return (Ok(st), rounds);
    }
    if token.is_cancelled() {
        return (cancelled(), 0);
    }
    let mut st = p.initial_table();
    let (r, rounds) = sweep::run_pooled_cancellable_counted(
        &ViterbiKernel::new(p, &mut st, NoRecord),
        pool,
        threads,
        token,
    );
    (r.map(|()| st), rounds)
}

/// [`execute_pooled`] + backpointer recording: state ownership keeps each
/// sidecar slot single-writer (DESIGN.md §8).
pub fn execute_pooled_recorded(
    p: &ViterbiProblem,
    pool: &ExecPool,
    threads: usize,
) -> (Vec<f64>, Vec<u32>) {
    let mut st = p.initial_table();
    let bp = SplitArena::new(st.len());
    sweep::run_pooled_counted(&ViterbiKernel::new(p, &mut st, &bp), pool, threads);
    (st, bp.into_vec())
}

/// Decode end to end: recorded fused solve + path walk — the router's
/// `want_solution` route.
pub fn solve_decoded(p: &ViterbiProblem) -> ViterbiSolution {
    let (st, bp) = execute_recorded(p);
    viterbi_path(p.num_states, &st, &bp)
}

/// Decode end to end on the process-wide pool — the router's pooled
/// `want_solution` route.
pub fn solve_pooled_decoded(p: &ViterbiProblem) -> ViterbiSolution {
    let pool = crate::runtime::exec_pool::global();
    let (st, bp) = execute_pooled_recorded(p, pool, pool.threads());
    viterbi_path(p.num_states, &st, &bp)
}

/// Convenience: pooled decode on the process-wide pool.
pub fn solve_pooled(p: &ViterbiProblem) -> Vec<f64> {
    let pool = crate::runtime::exec_pool::global();
    execute_pooled(p, pool, pool.threads())
}

/// Convenience: cancellable pooled decode on the process-wide pool.
pub fn solve_pooled_cancellable(p: &ViterbiProblem, token: &CancelToken) -> crate::Result<Vec<f64>> {
    let pool = crate::runtime::exec_pool::global();
    execute_pooled_cancellable(p, pool, pool.threads(), token)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;
    use crate::viterbi::seq;

    #[test]
    fn all_tiers_bit_identical_to_seq_oracle() {
        let pool = ExecPool::new(8);
        forall("viterbi tiers == seq", 30, |g| {
            let p = ViterbiProblem::random(g.rng(), 1..24, 7, 5);
            let (want_st, want_bp) = seq::solve_with_backpointers(&p);
            let fused = execute(&p);
            let (rst, rbp) = execute_recorded(&p);
            if fused != want_st || rst != want_st || rbp != want_bp {
                return Err(format!("fused diverged: {p:?}"));
            }
            for threads in [1usize, 2, 8] {
                let pooled = execute_pooled(&p, &pool, threads);
                let (pst, pbp) = execute_pooled_recorded(&p, &pool, threads);
                if pooled != want_st || pst != want_st || pbp != want_bp {
                    return Err(format!("pooled({threads}) diverged: {p:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn simd_column_kernel_bit_identical_to_seq_oracle() {
        forall("viterbi simd == seq (+backpointers)", 40, |g| {
            // S spans 1..14: below, at, and across lane-width boundaries
            let p = ViterbiProblem::random(g.rng(), 1..20, 13, 5);
            let (want_st, want_bp) = seq::solve_with_backpointers(&p);
            let st = execute_simd(&p);
            let (rst, rbp) = execute_simd_recorded(&p);
            // bit-identity, not approximate equality: compare the raw bits
            // so -0.0 vs +0.0 and NaN-free -inf propagation are pinned
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            if bits(&st) != bits(&want_st) || bits(&rst) != bits(&want_st) || rbp != want_bp {
                return Err(format!("simd diverged: {p:?}"));
            }
            if solve_simd_decoded(&p) != seq::decode(&p) {
                return Err(format!("simd decode diverged: {p:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn decoded_path_matches_seq_decode() {
        forall("viterbi decode == seq decode", 30, |g| {
            let p = ViterbiProblem::random(g.rng(), 1..16, 6, 4);
            let a = solve_decoded(&p);
            let b = seq::decode(&p);
            let c = solve_pooled_decoded(&p);
            if a == b && a == c {
                Ok(())
            } else {
                Err(format!("{a:?} vs {b:?} vs {c:?}: {p:?}"))
            }
        });
    }

    #[test]
    fn cancellable_with_never_or_live_token_matches_oracle() {
        let pool = ExecPool::new(4);
        forall("viterbi cancellable == seq", 20, |g| {
            let p = ViterbiProblem::random(g.rng(), 1..20, 6, 4);
            let want = seq::solve(&p);
            let live = CancelToken::after(std::time::Duration::from_secs(600));
            let a = execute_cancellable(&p, &CancelToken::never()).unwrap();
            let b = execute_cancellable(&p, &live).unwrap();
            let c = execute_pooled_cancellable(&p, &pool, 4, &live).unwrap();
            if a == want && b == want && c == want {
                Ok(())
            } else {
                Err(format!("{p:?}"))
            }
        });
    }

    #[test]
    fn expired_deadline_never_engages_the_pool() {
        let pool = ExecPool::new(4);
        let mut rng = crate::util::rng::Rng::seeded(17);
        let p = ViterbiProblem::random(&mut rng, 12..13, 6, 4);
        let expired = CancelToken::at(std::time::Instant::now());
        let before = pool.stats().solves;
        let (r, rounds) = execute_pooled_cancellable_counted(&p, &pool, 4, &expired);
        assert!(matches!(r, Err(crate::Error::Timeout(_))));
        assert_eq!(rounds, 0);
        assert_eq!(pool.stats().solves, before);
        // pool still serves afterwards
        assert_eq!(execute_pooled(&p, &pool, 4), seq::solve(&p));
    }

    #[test]
    fn pooled_superstep_barrier_budget_is_one_per_column() {
        // fixed S = 4 so the party clamp cannot collapse to the serial
        // fast path (which costs zero rounds)
        let half = (0.5f64).ln();
        let quarter = (0.25f64).ln();
        let p = ViterbiProblem::new(
            4,
            2,
            vec![quarter; 4],
            vec![quarter; 16],
            vec![half; 8],
            vec![0, 1, 0, 0, 1, 1, 0, 1, 0],
        )
        .unwrap();
        let pool = ExecPool::new(3);
        let (st, rounds) = execute_pooled_counted(&p, &pool, 3);
        assert_eq!(st, seq::solve(&p));
        assert_eq!(rounds as usize, p.num_steps() - 1);
    }
}
