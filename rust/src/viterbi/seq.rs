//! Sequential Viterbi oracle: the textbook `O(T·S²)` lattice fill.
//!
//! This is the tie-break reference for every pipeline executor
//! (DESIGN.md §8): candidates are scanned in ascending predecessor
//! order with a strictly-greater replacement rule, so the recorded
//! argmax is always the *lowest* maximizing predecessor, and state 0
//! stands in when every candidate is `−∞`.

use crate::core::problem::ViterbiProblem;
use crate::core::traceback::{viterbi_path, ViterbiSolution};

/// Fill the `T × S` lattice (flat, column-major in `t`: cell `(t, s)` is
/// index `t·S + s`): `V[t][s] = max_q(V[t−1][q] + trans[q][s]) +
/// emit[s][obs[t]]`, with column 0 from
/// [`ViterbiProblem::initial_table`].
pub fn solve(p: &ViterbiProblem) -> Vec<f64> {
    solve_with_backpointers(p).0
}

/// [`solve`] plus the per-cell argmax backpointers.  Column 0 has no
/// predecessor and keeps the arena's zero initialization — bit-identical
/// to the recorded sidecar of the pipeline executors.
pub fn solve_with_backpointers(p: &ViterbiProblem) -> (Vec<f64>, Vec<u32>) {
    let (s, m) = (p.num_states, p.num_symbols);
    let mut st = p.initial_table();
    let mut bp = vec![0u32; st.len()];
    for t in 1..p.num_steps() {
        for j in 0..s {
            let mut best = f64::NEG_INFINITY;
            let mut arg = 0u32;
            for q in 0..s {
                let cand = st[(t - 1) * s + q] + p.trans[q * s + j];
                if cand > best {
                    best = cand;
                    arg = q as u32;
                }
            }
            st[t * s + j] = best + p.emit[j * m + p.obs[t]];
            bp[t * s + j] = arg;
        }
    }
    (st, bp)
}

/// Decode the best path outright (oracle convenience for tests and the
/// Python golden harness).
pub fn decode(p: &ViterbiProblem) -> ViterbiSolution {
    let (st, bp) = solve_with_backpointers(p);
    viterbi_path(p.num_states, &st, &bp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;

    /// Exhaustive `S^T` path enumeration — ground truth for the DP.
    fn brute_best_score(p: &ViterbiProblem) -> f64 {
        let (s, m, t) = (p.num_states, p.num_symbols, p.num_steps());
        let mut best = f64::NEG_INFINITY;
        let mut path = vec![0usize; t];
        loop {
            let mut score = p.init[path[0]] + p.emit[path[0] * m + p.obs[0]];
            for i in 1..t {
                score += p.trans[path[i - 1] * s + path[i]] + p.emit[path[i] * m + p.obs[i]];
            }
            if score > best {
                best = score;
            }
            // odometer increment over the S^T path space
            let mut i = 0;
            loop {
                if i == t {
                    return best;
                }
                path[i] += 1;
                if path[i] < s {
                    break;
                }
                path[i] = 0;
                i += 1;
            }
        }
    }

    /// Log-likelihood of a concrete state path.
    fn path_score(p: &ViterbiProblem, states: &[u32]) -> f64 {
        let (s, m) = (p.num_states, p.num_symbols);
        let mut score = p.init[states[0] as usize] + p.emit[states[0] as usize * m + p.obs[0]];
        for i in 1..states.len() {
            score += p.trans[states[i - 1] as usize * s + states[i] as usize]
                + p.emit[states[i] as usize * m + p.obs[i]];
        }
        score
    }

    #[test]
    fn dp_score_matches_brute_force() {
        forall("viterbi seq == brute force", 40, |g| {
            // keep S^T enumerable
            let p = ViterbiProblem::random(g.rng(), 1..7, 5, 4);
            let sol = decode(&p);
            let want = brute_best_score(&p);
            let same = if want == f64::NEG_INFINITY {
                sol.score == f64::NEG_INFINITY
            } else {
                (sol.score - want).abs() < 1e-9
            };
            if !same {
                return Err(format!("score {} != brute {want}: {p:?}", sol.score));
            }
            // the reconstructed path must itself achieve the best score
            if sol.score > f64::NEG_INFINITY {
                let ps = path_score(&p, &sol.states);
                if (ps - sol.score).abs() > 1e-9 {
                    return Err(format!("path scores {ps}, table says {}: {p:?}", sol.score));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn impossible_observation_yields_neg_infinity() {
        // one state that can only emit symbol 0, observing symbol 1
        let p = ViterbiProblem::new(
            1,
            2,
            vec![0.0],
            vec![0.0],
            vec![0.0, f64::NEG_INFINITY],
            vec![0, 1],
        )
        .unwrap();
        let sol = decode(&p);
        assert_eq!(sol.score, f64::NEG_INFINITY);
        assert_eq!(sol.states, vec![0, 0], "tie-break pins state 0 throughout");
    }

    #[test]
    fn single_observation_picks_best_initial_state() {
        // two states: state 1 likelier to start and emit symbol 0
        let p = ViterbiProblem::new(
            2,
            1,
            vec![(0.25f64).ln(), (0.75f64).ln()],
            vec![(0.5f64).ln(); 4],
            vec![0.0, 0.0],
            vec![0],
        )
        .unwrap();
        let sol = decode(&p);
        assert_eq!(sol.states, vec![1]);
        assert!((sol.score - (0.75f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn ties_resolve_to_lowest_state() {
        // perfectly symmetric two-state HMM: every path ties, so the
        // pinned tie-break must return the all-zeros path
        let half = (0.5f64).ln();
        let p = ViterbiProblem::new(
            2,
            1,
            vec![half, half],
            vec![half; 4],
            vec![0.0, 0.0],
            vec![0, 0, 0],
        )
        .unwrap();
        let sol = decode(&p);
        assert_eq!(sol.states, vec![0, 0, 0]);
    }
}
