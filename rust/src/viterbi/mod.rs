//! Viterbi decoding — maximum-likelihood hidden-state paths of an HMM —
//! as a served DP family (DESIGN.md §11).
//!
//! The recurrence is the `(max, ×)` semiring in log space
//! ([`crate::core::semiring::LogMaxProb`]) swept over a `T × S` lattice
//! whose schedule is trivially hazard-free: column `t` depends only on
//! column `t − 1`, so each time step is one superstep and the generic
//! sweep drivers ([`crate::core::sweep`]) provide the fused, cancellable,
//! pooled and `_recorded` tiers without any family-specific loop code.
//!
//! * [`seq`] — the classic sequential oracle (and tie-break reference).
//! * [`pipeline`] — the [`crate::core::sweep`] instantiation the serving
//!   paths run, with backpointer recording into the shared
//!   [`crate::core::traceback::SplitArena`] sidecar.

pub mod pipeline;
pub mod seq;
