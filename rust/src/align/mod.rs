//! Sequence alignment — the O(1)-dependency grid-DP workload family
//! (LCS, edit distance, Smith–Waterman-style local alignment), opened to
//! prove the schedule arena and coordinator are problem-generic rather
//! than MCM-shaped (DESIGN.md §4).
//!
//! All three variants fill an `(m+1)×(n+1)` table whose cell `(i, j)`
//! depends only on `(i−1, j)`, `(i, j−1)` and `(i−1, j−1)` — the
//! canonical anti-diagonal wavefront shape (Helal et al.; Ding, Gu &
//! Sun).  Modules:
//!
//! * [`seq`] — classic row-major `O(mn)` DP: the oracle (plain and
//!   move-recording forms).
//! * [`wavefront`] — executors over the compiled
//!   [`crate::core::schedule::AlignSchedule`] flat arena: the fused
//!   step-synchronous sweep and the real multi-threaded executor with
//!   contiguous lane assignment.  Each has a `_recorded` sibling that
//!   additionally fills the packed 2-bit move sidecar
//!   ([`crate::core::traceback::MoveArena`]) from which
//!   [`crate::core::traceback::align_solution`] reconstructs the edit
//!   script, aligned pairs, and local span (DESIGN.md §8).

pub mod seq;
pub mod wavefront;
