//! Anti-diagonal wavefront executors over the compiled
//! [`AlignSchedule`] flat arena.
//!
//! Hazard-freedom (every operand of a step-`s` cell is final after step
//! `s−1` at the latest — property-checked in `core::conflict`) makes the
//! step-synchronous sweep *fusable*: the arena can be swept as one flat
//! loop with immediate writes, exactly like the corrected-MCM executor
//! (DESIGN.md §Perf / §4).  The threaded executor splits each step's
//! lanes across workers in contiguous chunks with one barrier per step —
//! reads land on earlier anti-diagonals (disjoint from the step's write
//! set) and writes are lane-distinct (Theorem 1 for the wavefront), so
//! the fused form is race-free.

use std::sync::Barrier;

use crate::align::seq;
use crate::core::cache;
use crate::core::problem::AlignProblem;
use crate::core::schedule::AlignSchedule;
use crate::sdp::naive::SharedTable;

/// Step-synchronous executor over a compiled schedule: one fused flat
/// sweep of the arena (sound by hazard-freedom; see module docs).
pub fn execute(p: &AlignProblem, sched: &AlignSchedule) -> Vec<i64> {
    assert_eq!(
        (p.rows(), p.cols()),
        (sched.rows, sched.cols),
        "schedule/problem size mismatch"
    );
    let mut st = p.initial_table();
    // one-time bounds validation of the whole arena (indices are grid- and
    // sequence-bounded by construction in AlignSchedule::compile)
    debug_assert!((0..sched.num_terms()).all(|i| {
        (sched.tgt[i] as usize) < st.len()
            && (sched.up[i] as usize) < st.len()
            && (sched.left[i] as usize) < st.len()
            && (sched.diag[i] as usize) < st.len()
            && (sched.ai[i] as usize) < p.a.len()
            && (sched.bj[i] as usize) < p.b.len()
    }));
    let variant = p.variant;
    let scoring = p.scoring;
    for i in 0..sched.num_terms() {
        let v = seq::cell(
            variant,
            &scoring,
            st[sched.up[i] as usize],
            st[sched.left[i] as usize],
            st[sched.diag[i] as usize],
            p.a[sched.ai[i] as usize],
            p.b[sched.bj[i] as usize],
        );
        st[sched.tgt[i] as usize] = v;
    }
    st
}

/// Convenience: fetch the `(rows, cols)` wavefront from the process-wide
/// schedule cache and execute.  Serving paths (the coordinator's native
/// route) land here, so a repeated grid shape never recompiles its
/// schedule.
pub fn solve(p: &AlignProblem) -> Vec<i64> {
    let sched = cache::align_schedule(p.rows(), p.cols());
    execute(p, &sched)
}

/// Real multi-threaded executor: the ≤ `min(m, n)` lanes of each step are
/// split across `threads` workers in contiguous chunks, one barrier per
/// step (the fused form — see module docs for why that is race-free).
pub fn execute_threaded(p: &AlignProblem, sched: &AlignSchedule, threads: usize) -> Vec<i64> {
    assert_eq!(
        (p.rows(), p.cols()),
        (sched.rows, sched.cols),
        "schedule/problem size mismatch"
    );
    let threads = threads.max(1).min(sched.max_width().max(1));
    if threads == 1 {
        return execute(p, sched);
    }
    let mut st = p.initial_table();
    let barrier = Barrier::new(threads);
    let st_ptr = SharedTable(st.as_mut_ptr());
    let variant = p.variant;
    let scoring = p.scoring;

    std::thread::scope(|scope| {
        for t in 0..threads {
            let barrier = &barrier;
            let st_ptr = &st_ptr;
            let a = &p.a;
            let b = &p.b;
            let scoring = &scoring;
            scope.spawn(move || {
                for s in 0..sched.num_steps() {
                    let view = sched.step_view(s);
                    let chunk = view.len().div_ceil(threads);
                    let lo = (t * chunk).min(view.len());
                    let hi = ((t + 1) * chunk).min(view.len());
                    for lane in lo..hi {
                        // SAFETY: reads are of cells finalized on earlier
                        // anti-diagonals (hazard-freedom), disjoint from
                        // this step's write set; writes are lane-distinct
                        // within a step (Theorem 1) — no data race.
                        unsafe {
                            let v = seq::cell(
                                variant,
                                scoring,
                                st_ptr.read(view.up[lane] as usize),
                                st_ptr.read(view.left[lane] as usize),
                                st_ptr.read(view.diag[lane] as usize),
                                a[view.ai[lane] as usize],
                                b[view.bj[lane] as usize],
                            );
                            st_ptr.write(view.tgt[lane] as usize, v);
                        }
                    }
                    barrier.wait(); // end of outer step
                }
            });
        }
    });
    st
}

/// Execution trace of the first `max_steps` wavefront steps (Fig. 7-style
/// walkthrough for the grid family).
pub fn trace(p: &AlignProblem, max_steps: usize) -> String {
    let sched = cache::align_schedule(p.rows(), p.cols());
    let mut out = format!(
        "alignment wavefront trace ({}), {}x{} grid, {} cells, {} steps, width ≤ {}\n",
        p.variant.name(),
        p.rows() + 1,
        p.cols() + 1,
        p.num_cells(),
        sched.num_steps(),
        sched.max_width()
    );
    for (s, view) in sched.steps().enumerate() {
        if s >= max_steps {
            out.push_str("…\n");
            break;
        }
        out.push_str(&format!("step {:>3}:", s + 1));
        for lane in 0..view.len() {
            let cols = sched.cols;
            let (i, j) = crate::core::schedule::grid::cell_coords(cols, view.tgt[lane] as usize);
            out.push_str(&format!("  T[{i},{j}]"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::problem::{AlignScoring, AlignVariant};
    use crate::prop::forall;

    #[test]
    fn wavefront_matches_oracle_property() {
        // the acceptance-criteria property: all three variants, sizes up
        // to 256 on a sparse tail so the suite stays fast
        forall("align wavefront == seq", 60, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let big = g.usize(0..10) == 0; // occasional large instance
            let range = if big { 128..257 } else { 1..48 };
            let p = AlignProblem::random(&mut rng, range, 4, v);
            let sched = crate::core::schedule::AlignSchedule::compile(p.rows(), p.cols());
            if execute(&p, &sched) == seq::solve(&p) {
                Ok(())
            } else {
                Err(format!("{:?} {}x{}", v, p.rows(), p.cols()))
            }
        });
    }

    #[test]
    fn threaded_matches_oracle() {
        forall("align threaded == seq", 20, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let p = AlignProblem::random(&mut rng, 4..64, 4, v);
            let threads = g.usize(2..5);
            let sched = crate::core::schedule::AlignSchedule::compile(p.rows(), p.cols());
            if execute_threaded(&p, &sched, threads) == seq::solve(&p) {
                Ok(())
            } else {
                Err(format!("{:?} {}x{} threads={threads}", v, p.rows(), p.cols()))
            }
        });
    }

    #[test]
    fn solve_uses_cached_schedule_and_matches() {
        let p = AlignProblem::lcs(vec![1, 2, 3, 4, 7], vec![2, 3, 9, 4]).unwrap();
        assert_eq!(solve(&p), seq::solve(&p));
        assert_eq!(p.scalar(&solve(&p)), 3); // LCS {2, 3, 4}
        // second solve of the same shape must hit the process-wide cache
        let before = crate::core::cache::global_stats().hits;
        let _ = solve(&p);
        assert!(crate::core::cache::global_stats().hits > before);
    }

    #[test]
    fn local_scoring_respected_by_wavefront() {
        let scoring = AlignScoring {
            match_s: 3,
            mismatch: -2,
            gap: -2,
        };
        let p = AlignProblem::new(
            vec![5, 1, 2, 3, 5],
            vec![8, 1, 2, 3, 8],
            AlignVariant::Local,
            scoring,
        )
        .unwrap();
        assert_eq!(p.scalar(&solve(&p)), 9); // 3 matches × 3
        assert_eq!(solve(&p), seq::solve(&p));
    }

    #[test]
    fn degenerate_single_symbol_grids() {
        for v in AlignVariant::ALL {
            let p =
                AlignProblem::new(vec![4], vec![4], v, AlignScoring::default()).unwrap();
            let sched = crate::core::schedule::AlignSchedule::compile(1, 1);
            assert_eq!(execute(&p, &sched), seq::solve(&p), "{v:?}");
        }
    }

    #[test]
    fn trace_shows_first_antidiagonal() {
        let p = AlignProblem::lcs(vec![1, 2], vec![3, 4]).unwrap();
        let t = trace(&p, 2);
        assert!(t.contains("T[1,1]"), "{t}");
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn executor_rejects_mismatched_schedule() {
        let p = AlignProblem::lcs(vec![1, 2], vec![3, 4]).unwrap();
        let sched = crate::core::schedule::AlignSchedule::compile(3, 3);
        execute(&p, &sched);
    }
}
