//! Anti-diagonal wavefront executors over the compiled
//! [`AlignSchedule`] flat arena.
//!
//! Hazard-freedom (every operand of a step-`s` cell is final after step
//! `s−1` at the latest — property-checked in `core::conflict`) makes the
//! step-synchronous sweep *fusable*: the arena can be swept as one flat
//! loop with immediate writes, exactly like the corrected-MCM executor
//! (DESIGN.md §Perf / §4).  The threaded executor splits each step's
//! lanes across workers in contiguous chunks with one barrier per step —
//! reads land on earlier anti-diagonals (disjoint from the step's write
//! set) and writes are lane-distinct (Theorem 1 for the wavefront), so
//! the fused form is race-free.

use std::sync::Barrier;

use crate::align::seq;
use crate::core::cache;
use crate::core::problem::AlignProblem;
use crate::core::schedule::{default_align_tile, AlignSchedule};
use crate::core::sweep::{self, SharedSlice, SweepKernel};
use crate::core::traceback::{cell_move, MoveArena, MoveRecord, NoRecord};
use crate::runtime::exec_pool::{cancelled, CancelToken, ExecPool, CANCEL_POLL_STRIDE};
use crate::sdp::naive::SharedTable;

/// The alignment recurrence packaged for the generic sweep drivers
/// (DESIGN.md §11).  Unlike MCM/CYK this is not a pure semiring lift —
/// [`seq::cell`] / [`cell_move`] fold the variant's border and
/// match/gap casework (a `(max, +)` algebra with per-variant affine
/// terms) — but the *sweep control* is identical, and that is what the
/// kernel deduplicates: the fused, cancellable, pooled and `_recorded`
/// tiers are monomorphized instantiations of one sweep.  `R = NoRecord`
/// compiles the plain table write; `R = &MoveArena` also publishes each
/// cell's 2-bit move code (write-once, DESIGN.md §8).
struct AlignKernel<'a, R: MoveRecord> {
    p: &'a AlignProblem,
    sched: &'a AlignSchedule,
    st: SharedSlice<i64>,
    rec: R,
}

impl<'a, R: MoveRecord> AlignKernel<'a, R> {
    fn new(p: &'a AlignProblem, sched: &'a AlignSchedule, st: &mut [i64], rec: R) -> Self {
        assert_eq!(
            (p.rows(), p.cols()),
            (sched.rows, sched.cols),
            "schedule/problem size mismatch"
        );
        debug_assert_eq!(st.len(), p.num_cells());
        AlignKernel {
            p,
            sched,
            st: SharedSlice::new(st.as_mut_ptr()),
            rec,
        }
    }

    /// One arena lane: gather the three neighbours, evaluate the
    /// variant's cell recurrence, write (and record) the target.
    ///
    /// # Safety
    /// `i < num_terms()`; the caller holds the sweep discipline — the
    /// lane's operands are finalized (earlier anti-diagonals or earlier
    /// cells of the calling party's own block) and the target cell is
    /// written by no other party this superstep.
    #[inline(always)]
    unsafe fn lane(&self, i: usize) {
        let sched = self.sched;
        // SAFETY: indices are grid- and sequence-bounded by construction
        // in AlignSchedule::compile (debug-asserted in `execute`); table
        // accesses are race-free by the caller's contract.
        unsafe {
            let up = self.st.read(*sched.up.get_unchecked(i) as usize);
            let left = self.st.read(*sched.left.get_unchecked(i) as usize);
            let diag = self.st.read(*sched.diag.get_unchecked(i) as usize);
            let av = *self.p.a.get_unchecked(*sched.ai.get_unchecked(i) as usize);
            let bv = *self.p.b.get_unchecked(*sched.bj.get_unchecked(i) as usize);
            let tgt = *sched.tgt.get_unchecked(i) as usize;
            if R::ACTIVE {
                let (v, code) =
                    cell_move(self.p.variant, &self.p.scoring, up, left, diag, av, bv);
                self.st.write(tgt, v);
                self.rec.set(tgt, code);
            } else {
                let v = seq::cell(self.p.variant, &self.p.scoring, up, left, diag, av, bv);
                self.st.write(tgt, v);
            }
        }
    }
}

impl<R: MoveRecord> SweepKernel for AlignKernel<'_, R> {
    fn num_supersteps(&self) -> usize {
        self.sched.num_steps()
    }

    unsafe fn superstep_party(&self, g: usize, party: usize, parties: usize) {
        // on a blocked schedule (tile > 1) a superstep is a
        // *block-anti-diagonal* and parties claim whole blocks
        // round-robin — each block sweeps sequentially in row-major
        // order (which satisfies every intra-block dependency), blocks
        // of one diagonal are mutually independent
        // (`core::conflict::align_tile_hazards` proves the fusion).  On
        // an untiled schedule each lane is a unit (classic wavefront).
        if self.sched.tile > 1 {
            for (k, u) in self.sched.step_unit_range(g).enumerate() {
                if k % parties != party {
                    continue;
                }
                for i in self.sched.unit_range(u) {
                    // SAFETY: unit ownership keeps intra-block reads on
                    // the writing party; everything else finalized
                    // behind a barrier (the caller's discipline).
                    unsafe { self.lane(i) };
                }
            }
        } else {
            for (k, i) in self.sched.step_range(g).enumerate() {
                if k % parties != party {
                    continue;
                }
                // SAFETY: reads land on earlier anti-diagonals, writes
                // are lane-distinct within a step (Theorem 1).
                unsafe { self.lane(i) };
            }
        }
    }

    unsafe fn sweep_serial(&self) {
        // flat single loop: hazard-freedom (every operand of a step-s
        // cell is final after step s−1 at the latest) makes the arena
        // sweepable as one fused loop — the §Perf hot path
        for i in 0..self.sched.num_terms() {
            // SAFETY: i < num_terms; serial discipline.
            unsafe { self.lane(i) };
        }
    }
}

/// Step-synchronous executor over a compiled schedule: one fused flat
/// sweep of the arena (sound by hazard-freedom; see module docs).
pub fn execute(p: &AlignProblem, sched: &AlignSchedule) -> Vec<i64> {
    assert_eq!(
        (p.rows(), p.cols()),
        (sched.rows, sched.cols),
        "schedule/problem size mismatch"
    );
    let mut st = p.initial_table();
    // one-time bounds validation of the whole arena (indices are grid- and
    // sequence-bounded by construction in AlignSchedule::compile)
    debug_assert!((0..sched.num_terms()).all(|i| {
        (sched.tgt[i] as usize) < st.len()
            && (sched.up[i] as usize) < st.len()
            && (sched.left[i] as usize) < st.len()
            && (sched.diag[i] as usize) < st.len()
            && (sched.ai[i] as usize) < p.a.len()
            && (sched.bj[i] as usize) < p.b.len()
    }));
    sweep::run_fused(&AlignKernel::new(p, sched, &mut st, NoRecord));
    st
}

/// Convenience: fetch the `(rows, cols)` wavefront from the process-wide
/// schedule cache and execute.  Serving paths (the coordinator's native
/// route) land here, so a repeated grid shape never recompiles its
/// schedule.
pub fn solve(p: &AlignProblem) -> Vec<i64> {
    let sched = cache::align_schedule(p.rows(), p.cols());
    execute(p, &sched)
}

/// [`execute`] with cooperative cancellation: the sweep runs
/// (block-)anti-diagonal by (block-)anti-diagonal, polling the
/// [`CancelToken`] every [`crate::runtime::exec_pool::CANCEL_POLL_STRIDE`]
/// steps and abandoning the
/// grid with `Err(Timeout)` once it fires.  A never-token delegates to
/// the fused flat sweep — the common path pays nothing.
pub fn execute_cancellable(
    p: &AlignProblem,
    sched: &AlignSchedule,
    token: &CancelToken,
) -> crate::Result<Vec<i64>> {
    if token.is_never() {
        return Ok(execute(p, sched));
    }
    let mut st = p.initial_table();
    sweep::run_cancellable(&AlignKernel::new(p, sched, &mut st, NoRecord), token)?;
    Ok(st)
}

/// [`execute`] + per-cell move recording (DESIGN.md §8): the fused flat
/// sweep evaluating [`crate::core::traceback::cell_move`] per lane and
/// publishing each cell's 2-bit code into the packed sidecar.  Each cell
/// is written exactly once — the same write-once invariant the table
/// itself has — so recording adds no hazards.
pub fn execute_recorded(p: &AlignProblem, sched: &AlignSchedule) -> (Vec<i64>, MoveArena) {
    assert_eq!(
        (p.rows(), p.cols()),
        (sched.rows, sched.cols),
        "schedule/problem size mismatch"
    );
    let mut st = p.initial_table();
    let moves = MoveArena::new(st.len());
    sweep::run_fused(&AlignKernel::new(p, sched, &mut st, &moves));
    (st, moves)
}

/// Convenience: recorded solve over the cached untiled wavefront — the
/// router's `fused` traceback route.
pub fn solve_recorded(p: &AlignProblem) -> (Vec<i64>, MoveArena) {
    let sched = cache::align_schedule(p.rows(), p.cols());
    execute_recorded(p, &sched)
}

/// Lane width of the striped wavefront batches.  Matches
/// [`crate::core::simd::LANES`]; the batch kernels below are plain
/// fixed-width array loops, so the value only has to be a size the
/// autovectorizer likes — 8 × i64 is one cache line and two AVX2
/// registers.
const WF_LANES: usize = 8;

/// The gathered operand strips of one lane batch: lane `k` holds the
/// three stencil neighbors and the symbol-equality flag of cell
/// `(i + k, d − i − k)` on anti-diagonal `d`.
struct LaneOps {
    up: [i64; WF_LANES],
    left: [i64; WF_LANES],
    diag: [i64; WF_LANES],
    eq: [bool; WF_LANES],
}

/// One lane-batch of the alignment recurrence — [`seq::cell`] evaluated
/// on `WF_LANES` independent cells of one anti-diagonal.  Written as
/// branch-free per-lane selects over fixed-width arrays (no `std::arch`,
/// no `unsafe`) so the compiler can lower each variant to vector
/// blends; lane semantics are *identical* to the scalar recurrence, so
/// results are bit-for-bit equal by construction, not by rounding
/// accident (everything here is integer arithmetic).
#[inline(always)]
fn batch_cell(
    variant: crate::core::problem::AlignVariant,
    scoring: &crate::core::problem::AlignScoring,
    ops: &LaneOps,
    out: &mut [i64; WF_LANES],
) {
    use crate::core::problem::AlignVariant;
    match variant {
        AlignVariant::Lcs => {
            for k in 0..WF_LANES {
                out[k] = if ops.eq[k] {
                    ops.diag[k] + 1
                } else {
                    ops.up[k].max(ops.left[k])
                };
            }
        }
        AlignVariant::Edit => {
            for k in 0..WF_LANES {
                let sub = ops.diag[k] + i64::from(!ops.eq[k]);
                out[k] = sub.min(ops.up[k] + 1).min(ops.left[k] + 1);
            }
        }
        AlignVariant::Local => {
            for k in 0..WF_LANES {
                let s = if ops.eq[k] { scoring.match_s } else { scoring.mismatch };
                out[k] = (ops.diag[k] + s)
                    .max(ops.up[k] + scoring.gap)
                    .max(ops.left[k] + scoring.gap)
                    .max(0);
            }
        }
    }
}

/// [`batch_cell`] + per-lane move codes — the lane-batched form of
/// [`cell_move`], preserving its exact preference order (`DIAG` over
/// `UP` over `LEFT`, `STOP` on a zero-clamped Local cell) so the
/// recorded sidecar is bit-identical to the sequential oracle's.
#[inline(always)]
fn batch_cell_move(
    variant: crate::core::problem::AlignVariant,
    scoring: &crate::core::problem::AlignScoring,
    ops: &LaneOps,
    out: &mut [i64; WF_LANES],
    codes: &mut [u8; WF_LANES],
) {
    use crate::core::problem::AlignVariant;
    use crate::core::traceback::{MOVE_DIAG, MOVE_LEFT, MOVE_STOP, MOVE_UP};
    match variant {
        AlignVariant::Lcs => {
            for k in 0..WF_LANES {
                let (v, c) = if ops.eq[k] {
                    (ops.diag[k] + 1, MOVE_DIAG)
                } else if ops.up[k] >= ops.left[k] {
                    (ops.up[k], MOVE_UP)
                } else {
                    (ops.left[k], MOVE_LEFT)
                };
                out[k] = v;
                codes[k] = c;
            }
        }
        AlignVariant::Edit => {
            for k in 0..WF_LANES {
                let sub = ops.diag[k] + i64::from(!ops.eq[k]);
                let best = sub.min(ops.up[k] + 1).min(ops.left[k] + 1);
                out[k] = best;
                codes[k] = if sub == best {
                    MOVE_DIAG
                } else if ops.up[k] + 1 == best {
                    MOVE_UP
                } else {
                    MOVE_LEFT
                };
            }
        }
        AlignVariant::Local => {
            for k in 0..WF_LANES {
                let s = if ops.eq[k] { scoring.match_s } else { scoring.mismatch };
                let (d, u, l) = (
                    ops.diag[k] + s,
                    ops.up[k] + scoring.gap,
                    ops.left[k] + scoring.gap,
                );
                let best = d.max(u).max(l).max(0);
                out[k] = best;
                codes[k] = if best == 0 {
                    MOVE_STOP
                } else if d == best {
                    MOVE_DIAG
                } else if u == best {
                    MOVE_UP
                } else {
                    MOVE_LEFT
                };
            }
        }
    }
}

/// The striped anti-diagonal sweep (ISSUE 9 tentpole, DESIGN.md §12):
/// walk the grid wavefront by wavefront, but instead of the arena
/// schedule, enumerate each diagonal's cells directly and process them
/// `WF_LANES` at a time — gather the `up`/`left`/`diag` strips into
/// fixed-width lane buffers, run the branch-free batch kernel, scatter
/// the results back.  The ragged head/tail of each diagonal falls back
/// to the scalar [`seq::cell`] / [`cell_move`], so every cell is
/// evaluated by a recurrence bit-identical to the oracle's.
///
/// No schedule is compiled or cached — the diagonal arithmetic *is* the
/// schedule, which is why this executor wins at every size (no arena
/// traffic, no barrier, no compile amortization cliff).
fn simd_sweep<R: MoveRecord>(
    p: &AlignProblem,
    st: &mut [i64],
    rec: R,
    token: Option<&CancelToken>,
) -> crate::Result<()> {
    let (m, n) = (p.rows(), p.cols());
    let w = n + 1; // row stride of the (m+1)×(n+1) table
    for d in 2..=(m + n) {
        if let Some(tok) = token {
            if d % CANCEL_POLL_STRIDE == 0 && tok.is_cancelled() {
                return cancelled();
            }
        }
        // cells (i, j) with i + j = d, 1 ≤ i ≤ m, 1 ≤ j ≤ n
        let i_lo = 1usize.max(d.saturating_sub(n));
        let i_hi = m.min(d - 1);
        let mut i = i_lo;
        while i + WF_LANES <= i_hi + 1 {
            let mut ops = LaneOps {
                up: [0; WF_LANES],
                left: [0; WF_LANES],
                diag: [0; WF_LANES],
                eq: [false; WF_LANES],
            };
            for k in 0..WF_LANES {
                let (ii, jj) = (i + k, d - (i + k));
                ops.up[k] = st[(ii - 1) * w + jj];
                ops.left[k] = st[ii * w + jj - 1];
                ops.diag[k] = st[(ii - 1) * w + jj - 1];
                ops.eq[k] = p.a[ii - 1] == p.b[jj - 1];
            }
            let mut out = [0i64; WF_LANES];
            if R::ACTIVE {
                let mut codes = [0u8; WF_LANES];
                batch_cell_move(p.variant, &p.scoring, &ops, &mut out, &mut codes);
                for k in 0..WF_LANES {
                    let (ii, jj) = (i + k, d - (i + k));
                    st[ii * w + jj] = out[k];
                    rec.set(ii * w + jj, codes[k]);
                }
            } else {
                batch_cell(p.variant, &p.scoring, &ops, &mut out);
                for k in 0..WF_LANES {
                    let (ii, jj) = (i + k, d - (i + k));
                    st[ii * w + jj] = out[k];
                }
            }
            i += WF_LANES;
        }
        // ragged tail: scalar recurrence, bit-identical by sharing
        // seq::cell / cell_move with the oracle
        while i <= i_hi {
            let jj = d - i;
            let up = st[(i - 1) * w + jj];
            let left = st[i * w + jj - 1];
            let diag = st[(i - 1) * w + jj - 1];
            let (av, bv) = (p.a[i - 1], p.b[jj - 1]);
            if R::ACTIVE {
                let (v, code) = cell_move(p.variant, &p.scoring, up, left, diag, av, bv);
                st[i * w + jj] = v;
                rec.set(i * w + jj, code);
            } else {
                st[i * w + jj] = seq::cell(p.variant, &p.scoring, up, left, diag, av, bv);
            }
            i += 1;
        }
    }
    Ok(())
}

/// Lane-batched anti-diagonal solve — the adaptive policy's `simd`
/// route.  Bit-identical to [`seq::solve`] (shared scalar recurrence on
/// the tails, lane-equivalent batch kernel elsewhere; all integer
/// arithmetic).
pub fn solve_simd(p: &AlignProblem) -> Vec<i64> {
    let mut st = p.initial_table();
    let _ = simd_sweep(p, &mut st, NoRecord, None);
    st
}

/// [`solve_simd`] + per-cell move recording — the `simd` traceback
/// route.  The batched move kernel preserves [`cell_move`]'s preference
/// order, so the sidecar is bit-identical to the sequential oracle's.
pub fn solve_simd_recorded(p: &AlignProblem) -> (Vec<i64>, MoveArena) {
    let mut st = p.initial_table();
    let moves = MoveArena::new(st.len());
    let _ = simd_sweep(p, &mut st, &moves, None);
    (st, moves)
}

/// [`solve_simd`] with cooperative cancellation, polling once per
/// [`CANCEL_POLL_STRIDE`] anti-diagonals.  A never-token delegates to
/// the plain sweep.
pub fn solve_simd_cancellable(p: &AlignProblem, token: &CancelToken) -> crate::Result<Vec<i64>> {
    if token.is_never() {
        return Ok(solve_simd(p));
    }
    if token.is_cancelled() {
        return cancelled();
    }
    let mut st = p.initial_table();
    simd_sweep(p, &mut st, NoRecord, Some(token))?;
    Ok(st)
}

/// Real multi-threaded executor: the ≤ `min(m, n)` lanes of each step are
/// split across `threads` workers in contiguous chunks, one barrier per
/// step (the fused form — see module docs for why that is race-free).
pub fn execute_threaded(p: &AlignProblem, sched: &AlignSchedule, threads: usize) -> Vec<i64> {
    assert_eq!(
        (p.rows(), p.cols()),
        (sched.rows, sched.cols),
        "schedule/problem size mismatch"
    );
    // a block-tiled schedule's "steps" have intra-step dependencies
    // (cells within a block); splitting their lanes into per-thread
    // chunks would race — only the unit-aware pooled executor may run
    // tiled schedules
    assert_eq!(
        sched.tile, 1,
        "execute_threaded requires an untiled schedule; use execute_pooled for tiled ones"
    );
    let threads = threads.max(1).min(sched.max_width().max(1));
    if threads == 1 {
        return execute(p, sched);
    }
    let mut st = p.initial_table();
    let barrier = Barrier::new(threads);
    let st_ptr = SharedTable(st.as_mut_ptr());
    let variant = p.variant;
    let scoring = p.scoring;

    std::thread::scope(|scope| {
        for t in 0..threads {
            let barrier = &barrier;
            let st_ptr = &st_ptr;
            let a = &p.a;
            let b = &p.b;
            let scoring = &scoring;
            scope.spawn(move || {
                for s in 0..sched.num_steps() {
                    let view = sched.step_view(s);
                    let chunk = view.len().div_ceil(threads);
                    let lo = (t * chunk).min(view.len());
                    let hi = ((t + 1) * chunk).min(view.len());
                    for lane in lo..hi {
                        // SAFETY: reads are of cells finalized on earlier
                        // anti-diagonals (hazard-freedom), disjoint from
                        // this step's write set; writes are lane-distinct
                        // within a step (Theorem 1) — no data race.
                        unsafe {
                            let v = seq::cell(
                                variant,
                                scoring,
                                st_ptr.read(view.up[lane] as usize),
                                st_ptr.read(view.left[lane] as usize),
                                st_ptr.read(view.diag[lane] as usize),
                                a[view.ai[lane] as usize],
                                b[view.bj[lane] as usize],
                            );
                            st_ptr.write(view.tgt[lane] as usize, v);
                        }
                    }
                    barrier.wait(); // end of outer step
                }
            });
        }
    });
    st
}

/// [`execute_threaded`] + move recording.  The packed sidecar is safe
/// under the same argument as the table: writes are lane-distinct within
/// a step, and the [`MoveArena`]'s relaxed `fetch_or` publication makes
/// byte-sharing neighbours race-free (DESIGN.md §8).
pub fn execute_threaded_recorded(
    p: &AlignProblem,
    sched: &AlignSchedule,
    threads: usize,
) -> (Vec<i64>, MoveArena) {
    assert_eq!(
        (p.rows(), p.cols()),
        (sched.rows, sched.cols),
        "schedule/problem size mismatch"
    );
    assert_eq!(
        sched.tile, 1,
        "execute_threaded requires an untiled schedule; use execute_pooled for tiled ones"
    );
    let threads = threads.max(1).min(sched.max_width().max(1));
    if threads == 1 {
        return execute_recorded(p, sched);
    }
    let mut st = p.initial_table();
    let moves = MoveArena::new(st.len());
    let barrier = Barrier::new(threads);
    let st_ptr = SharedTable(st.as_mut_ptr());
    let variant = p.variant;
    let scoring = p.scoring;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let barrier = &barrier;
            let st_ptr = &st_ptr;
            let moves = &moves;
            let a = &p.a;
            let b = &p.b;
            let scoring = &scoring;
            scope.spawn(move || {
                for s in 0..sched.num_steps() {
                    let view = sched.step_view(s);
                    let chunk = view.len().div_ceil(threads);
                    let lo = (t * chunk).min(view.len());
                    let hi = ((t + 1) * chunk).min(view.len());
                    for lane in lo..hi {
                        // SAFETY: as in `execute_threaded`; the sidecar
                        // write is the cell's only one and is atomic.
                        unsafe {
                            let (v, code) = cell_move(
                                variant,
                                scoring,
                                st_ptr.read(view.up[lane] as usize),
                                st_ptr.read(view.left[lane] as usize),
                                st_ptr.read(view.diag[lane] as usize),
                                a[view.ai[lane] as usize],
                                b[view.bj[lane] as usize],
                            );
                            st_ptr.write(view.tgt[lane] as usize, v);
                            moves.set(view.tgt[lane] as usize, code);
                        }
                    }
                    barrier.wait(); // end of outer step
                }
            });
        }
    });
    (st, moves)
}

/// Pooled tiled executor (DESIGN.md §7): resident [`ExecPool`] workers,
/// one [`crate::runtime::exec_pool::SenseBarrier`] wait per step.  On a
/// blocked schedule
/// (`tile > 1`) a step is a *block-anti-diagonal* and workers claim whole
/// blocks round-robin — each block is swept sequentially in row-major
/// order (which satisfies every intra-block dependency), blocks of one
/// diagonal are mutually independent, so `⌈m/B⌉ + ⌈n/B⌉ − 1` barriers
/// replace the cell-wavefront's `m + n − 1`
/// ([`crate::core::conflict::align_tile_hazards`] proves the fusion).
/// On an untiled schedule each *lane* is a unit (classic wavefront,
/// barrier per anti-diagonal) — correct, but without the barrier
/// amortization.
pub fn execute_pooled(
    p: &AlignProblem,
    sched: &AlignSchedule,
    pool: &ExecPool,
    threads: usize,
) -> Vec<i64> {
    execute_pooled_counted(p, sched, pool, threads).0
}

/// [`execute_pooled`] + the number of barrier rounds it cost (the
/// sync-budget hook the superstep tests assert on).
pub fn execute_pooled_counted(
    p: &AlignProblem,
    sched: &AlignSchedule,
    pool: &ExecPool,
    threads: usize,
) -> (Vec<i64>, u64) {
    let mut st = p.initial_table();
    let rounds =
        sweep::run_pooled_counted(&AlignKernel::new(p, sched, &mut st, NoRecord), pool, threads);
    (st, rounds)
}

/// [`execute_pooled`] with cooperative cancellation via the superstep
/// cut protocol: party 0 polls the [`CancelToken`] at the *end* of each
/// (block-)anti-diagonal and publishes the first step index every party
/// must skip, *before* its barrier wait.  The break check compares step
/// indices rather than a boolean, so a party that happens to observe the
/// publication within the very step it was made still finishes that step
/// and breaks one barrier later — all parties perform identical barrier
/// waits (an inconsistent boolean flag could strand the barrier with a
/// missing arrival), and the pool is released within one barrier round
/// of the deadline firing.  An expired-at-entry token never engages the
/// pool (zero barrier rounds).
pub fn execute_pooled_cancellable(
    p: &AlignProblem,
    sched: &AlignSchedule,
    pool: &ExecPool,
    threads: usize,
    token: &CancelToken,
) -> crate::Result<Vec<i64>> {
    execute_pooled_cancellable_counted(p, sched, pool, threads, token).0
}

/// [`execute_pooled_cancellable`] + the number of barrier rounds it cost
/// — the hook the cancellation-latency tests assert on.
pub fn execute_pooled_cancellable_counted(
    p: &AlignProblem,
    sched: &AlignSchedule,
    pool: &ExecPool,
    threads: usize,
    token: &CancelToken,
) -> (crate::Result<Vec<i64>>, u64) {
    if token.is_never() {
        let (st, rounds) = execute_pooled_counted(p, sched, pool, threads);
        return (Ok(st), rounds);
    }
    if token.is_cancelled() {
        return (cancelled(), 0);
    }
    let mut st = p.initial_table();
    let (r, rounds) = sweep::run_pooled_cancellable_counted(
        &AlignKernel::new(p, sched, &mut st, NoRecord),
        pool,
        threads,
        token,
    );
    (r.map(|()| st), rounds)
}

/// [`execute_pooled`] + move recording: block (or lane) ownership keeps
/// each cell's single sidecar write on the worker computing it, and the
/// [`MoveArena`]'s atomic publication covers byte-sharing across block
/// boundaries (DESIGN.md §8).
pub fn execute_pooled_recorded(
    p: &AlignProblem,
    sched: &AlignSchedule,
    pool: &ExecPool,
    threads: usize,
) -> (Vec<i64>, MoveArena) {
    let mut st = p.initial_table();
    let moves = MoveArena::new(st.len());
    sweep::run_pooled_counted(&AlignKernel::new(p, sched, &mut st, &moves), pool, threads);
    (st, moves)
}

/// Convenience: recorded solve on the process-wide pool with the cached
/// default-blocked schedule — the router's `pooled` traceback route.
/// Falls back to the fused recorded sweep for grids whose short side
/// does not exceed the block tile, like [`solve_pooled`].
pub fn solve_pooled_recorded(p: &AlignProblem) -> (Vec<i64>, MoveArena) {
    let (rows, cols) = (p.rows(), p.cols());
    let tile = default_align_tile(rows, cols);
    if rows.min(cols) <= tile {
        return solve_recorded(p);
    }
    let sched = cache::align_schedule_tiled(rows, cols, tile);
    let pool = crate::runtime::exec_pool::global();
    execute_pooled_recorded(p, &sched, pool, pool.threads())
}

/// Convenience: solve on the process-wide pool with the cached
/// default-blocked schedule — the adaptive policy's `pooled` route.
///
/// Grids whose short side does not exceed the block tile have one block
/// per diagonal — nothing to spread across workers — and fall back to
/// the fused sweep (the policy keys align on the short side, so this is
/// a belt-and-suspenders guard, not the normal path).
pub fn solve_pooled(p: &AlignProblem) -> Vec<i64> {
    let (rows, cols) = (p.rows(), p.cols());
    let tile = default_align_tile(rows, cols);
    if rows.min(cols) <= tile {
        return solve(p);
    }
    let sched = cache::align_schedule_tiled(rows, cols, tile);
    let pool = crate::runtime::exec_pool::global();
    execute_pooled(p, &sched, pool, pool.threads())
}

/// Convenience: cancellable solve over the cached untiled wavefront —
/// the router's deadline-carrying `seq`/`fused` route.
pub fn solve_cancellable(p: &AlignProblem, token: &CancelToken) -> crate::Result<Vec<i64>> {
    let sched = cache::align_schedule(p.rows(), p.cols());
    execute_cancellable(p, &sched, token)
}

/// Convenience: cancellable pooled solve on the process-wide pool — the
/// router's deadline-carrying `pooled` route.  Falls back to the fused
/// cancellable sweep for grids with one block per diagonal, like
/// [`solve_pooled`].
pub fn solve_pooled_cancellable(
    p: &AlignProblem,
    token: &CancelToken,
) -> crate::Result<Vec<i64>> {
    let (rows, cols) = (p.rows(), p.cols());
    let tile = default_align_tile(rows, cols);
    if rows.min(cols) <= tile {
        return solve_cancellable(p, token);
    }
    let sched = cache::align_schedule_tiled(rows, cols, tile);
    let pool = crate::runtime::exec_pool::global();
    execute_pooled_cancellable(p, &sched, pool, pool.threads(), token)
}

/// Execution trace of the first `max_steps` wavefront steps (Fig. 7-style
/// walkthrough for the grid family).
pub fn trace(p: &AlignProblem, max_steps: usize) -> String {
    let sched = cache::align_schedule(p.rows(), p.cols());
    let mut out = format!(
        "alignment wavefront trace ({}), {}x{} grid, {} cells, {} steps, width ≤ {}\n",
        p.variant.name(),
        p.rows() + 1,
        p.cols() + 1,
        p.num_cells(),
        sched.num_steps(),
        sched.max_width()
    );
    for (s, view) in sched.steps().enumerate() {
        if s >= max_steps {
            out.push_str("…\n");
            break;
        }
        out.push_str(&format!("step {:>3}:", s + 1));
        for lane in 0..view.len() {
            let cols = sched.cols;
            let (i, j) = crate::core::schedule::grid::cell_coords(cols, view.tgt[lane] as usize);
            out.push_str(&format!("  T[{i},{j}]"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::problem::{AlignScoring, AlignVariant};
    use crate::prop::forall;

    #[test]
    fn wavefront_matches_oracle_property() {
        // the acceptance-criteria property: all three variants, sizes up
        // to 256 on a sparse tail so the suite stays fast
        forall("align wavefront == seq", 60, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let big = g.usize(0..10) == 0; // occasional large instance
            let range = if big { 128..257 } else { 1..48 };
            let p = AlignProblem::random(&mut rng, range, 4, v);
            let sched = crate::core::schedule::AlignSchedule::compile(p.rows(), p.cols());
            if execute(&p, &sched) == seq::solve(&p) {
                Ok(())
            } else {
                Err(format!("{:?} {}x{}", v, p.rows(), p.cols()))
            }
        });
    }

    #[test]
    fn simd_matches_seq_oracle_bit_for_bit_including_moves() {
        // ISSUE 9 satellite (c): scores AND the recorded 2-bit sidecar
        // bit-identical to the sequential oracle across all variants,
        // with sizes straddling the lane width so ragged heads/tails and
        // non-multiple-of-8 diagonals are exercised
        forall("align simd == seq", 60, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let big = g.usize(0..10) == 0;
            let range = if big { 100..200 } else { 1..45 };
            let p = AlignProblem::random(&mut rng, range, 4, v);
            let want = seq::solve(&p);
            if solve_simd(&p) != want {
                return Err(format!("{v:?} {}x{} table", p.rows(), p.cols()));
            }
            let (st, moves) = solve_simd_recorded(&p);
            if st != want {
                return Err(format!("{v:?} {}x{} recorded table", p.rows(), p.cols()));
            }
            let (_, want_moves) = seq::solve_with_moves(&p);
            for idx in 0..st.len() {
                if moves.get(idx) != want_moves.get(idx) {
                    return Err(format!("{v:?}: move mismatch at cell {idx}"));
                }
            }
            // cancellable tier: never/live tokens match, expired cancels
            let live = CancelToken::after(std::time::Duration::from_secs(600));
            if solve_simd_cancellable(&p, &CancelToken::never()).unwrap() != want
                || solve_simd_cancellable(&p, &live).unwrap() != want
            {
                return Err(format!("{v:?} cancellable mismatch"));
            }
            let expired = CancelToken::at(std::time::Instant::now());
            if !matches!(
                solve_simd_cancellable(&p, &expired),
                Err(crate::Error::Timeout(_))
            ) {
                return Err("expired token must cancel the simd sweep".into());
            }
            Ok(())
        });
    }

    #[test]
    fn threaded_matches_oracle() {
        forall("align threaded == seq", 20, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let p = AlignProblem::random(&mut rng, 4..64, 4, v);
            let threads = g.usize(2..5);
            let sched = crate::core::schedule::AlignSchedule::compile(p.rows(), p.cols());
            if execute_threaded(&p, &sched, threads) == seq::solve(&p) {
                Ok(())
            } else {
                Err(format!("{:?} {}x{} threads={threads}", v, p.rows(), p.cols()))
            }
        });
    }

    #[test]
    fn pooled_tiled_matches_oracle_across_threads() {
        // the ISSUE's property matrix: block sizes × threads ∈
        // {1, 2, 3, 8} × non-divisible grids × all variants, against the
        // row-major oracle
        let pool = ExecPool::new(8);
        forall("align pooled == seq", 24, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let p = AlignProblem::random(&mut rng, 1..70, 4, v);
            let tile = *g.choose(&[1usize, 2, 3, 8, 16]);
            let threads = *g.choose(&[1usize, 2, 3, 8]);
            let sched =
                crate::core::schedule::AlignSchedule::compile_tiled(p.rows(), p.cols(), tile);
            if execute_pooled(&p, &sched, &pool, threads) == seq::solve(&p) {
                Ok(())
            } else {
                Err(format!(
                    "{v:?} {}x{} tile={tile} threads={threads}",
                    p.rows(),
                    p.cols()
                ))
            }
        });
    }

    #[test]
    fn cancellable_with_never_or_live_token_matches_oracle() {
        let pool = ExecPool::new(4);
        forall("align cancellable == seq", 20, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let p = AlignProblem::random(&mut rng, 2..60, 4, v);
            let tile = *g.choose(&[1usize, 3, 8]);
            let threads = *g.choose(&[1usize, 2, 4]);
            let want = seq::solve(&p);
            let sched =
                crate::core::schedule::AlignSchedule::compile_tiled(p.rows(), p.cols(), tile);
            let live = CancelToken::after(std::time::Duration::from_secs(600));
            let a = execute_cancellable(&p, &sched, &CancelToken::never()).unwrap();
            let b = execute_cancellable(&p, &sched, &live).unwrap();
            let c = execute_pooled_cancellable(&p, &sched, &pool, threads, &live).unwrap();
            if a == want && b == want && c == want {
                Ok(())
            } else {
                Err(format!(
                    "{v:?} {}x{} tile={tile} threads={threads}",
                    p.rows(),
                    p.cols()
                ))
            }
        });
    }

    #[test]
    fn expired_deadline_cancels_with_zero_rounds_and_pool_idle() {
        let pool = ExecPool::new(4);
        let mut rng = crate::util::rng::Rng::seeded(41);
        let p = AlignProblem::random(&mut rng, 40..41, 4, AlignVariant::Lcs);
        let sched =
            crate::core::schedule::AlignSchedule::compile_tiled(p.rows(), p.cols(), 4);
        let expired = CancelToken::at(std::time::Instant::now());
        let before = pool.stats().solves;
        let (r, rounds) =
            execute_pooled_cancellable_counted(&p, &sched, &pool, 4, &expired);
        assert!(matches!(r, Err(crate::Error::Timeout(_))));
        assert_eq!(rounds, 0, "entry gate must not engage the pool");
        assert_eq!(pool.stats().solves, before);
        assert_eq!(pool.stats().active, 0);
        assert!(matches!(
            execute_cancellable(&p, &sched, &expired),
            Err(crate::Error::Timeout(_))
        ));
        // the pool still serves after the cancellation
        assert_eq!(execute_pooled(&p, &sched, &pool, 4), seq::solve(&p));
    }

    #[test]
    fn recorded_solution_cost_matches_oracle_property() {
        // the ISSUE's property matrix: reconstruction from the pipeline
        // sidecar replays to the sequential oracle's score on random
        // instances up to n = 128, all variants, threads ∈ {1, 2, 8}
        use crate::core::traceback::align_solution;
        let pool = ExecPool::new(8);
        forall("recorded solution replay == oracle", 40, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let big = g.usize(0..8) == 0;
            let range = if big { 64..129 } else { 1..48 };
            let p = AlignProblem::random(&mut rng, range, 4, v);
            let want = seq::score(&p);
            let threads = *g.choose(&[1usize, 2, 8]);
            let sched =
                crate::core::schedule::AlignSchedule::compile(p.rows(), p.cols());
            let (st, moves) = execute_threaded_recorded(&p, &sched, threads);
            let sol = align_solution(&p, &st, &moves);
            if sol.score != want {
                return Err(format!(
                    "{v:?} {}x{} threads={threads}: {} != {want}",
                    p.rows(),
                    p.cols(),
                    sol.score
                ));
            }
            let tile = *g.choose(&[2usize, 3, 8]);
            let tsched = crate::core::schedule::AlignSchedule::compile_tiled(
                p.rows(),
                p.cols(),
                tile,
            );
            let (pst, pmoves) = execute_pooled_recorded(&p, &tsched, &pool, threads);
            let psol = align_solution(&p, &pst, &pmoves);
            if psol.score != want {
                return Err(format!(
                    "{v:?} {}x{} pooled tile={tile} threads={threads}: {} != {want}",
                    p.rows(),
                    p.cols(),
                    psol.score
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn recorded_moves_exactly_match_seq_tiebreak() {
        // bit-identical sidecars under the deterministic tie-break —
        // fused, threaded and pooled recorders vs the sequential oracle
        let pool = ExecPool::new(3);
        forall("recorded moves == seq moves", 30, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let p = AlignProblem::random(&mut rng, 1..50, 4, v);
            let (want_st, want_moves) = seq::solve_with_moves(&p);
            let sched =
                crate::core::schedule::AlignSchedule::compile(p.rows(), p.cols());
            let (st, moves) = execute_recorded(&p, &sched);
            if st != want_st {
                return Err(format!("{v:?}: fused table diverged"));
            }
            let tsched =
                crate::core::schedule::AlignSchedule::compile_tiled(p.rows(), p.cols(), 4);
            let (_, tmoves) = execute_threaded_recorded(&p, &sched, 3);
            let (_, pmoves) = execute_pooled_recorded(&p, &tsched, &pool, 3);
            for idx in 0..want_st.len() {
                let w = want_moves.get(idx);
                if moves.get(idx) != w || tmoves.get(idx) != w || pmoves.get(idx) != w {
                    return Err(format!("{v:?}: move mismatch at cell {idx}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn generic_sweep_bit_identical_to_legacy_threaded() {
        // DESIGN.md §11 regression pin: the generic-sweep tiers must
        // reproduce the hand-rolled chunked-threaded executors
        // bit-for-bit — table values AND 2-bit move codes — across the
        // threads × tile matrix and all variants.
        let pool = ExecPool::new(8);
        forall("align semiring sweep == legacy", 16, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let p = AlignProblem::random(&mut rng, 1..48, 4, v);
            let sched = crate::core::schedule::AlignSchedule::compile(p.rows(), p.cols());
            let (want_st, want_mv) = seq::solve_with_moves(&p);
            let (fst, fmv) = execute_recorded(&p, &sched);
            if fst != want_st {
                return Err(format!("{v:?}: fused table diverged"));
            }
            for threads in [1usize, 2, 8] {
                let (lst, lmv) = execute_threaded_recorded(&p, &sched, threads);
                if lst != want_st {
                    return Err(format!("{v:?}: legacy table diverged (threads={threads})"));
                }
                for tile in [1usize, 4, 8] {
                    let tsched = crate::core::schedule::AlignSchedule::compile_tiled(
                        p.rows(),
                        p.cols(),
                        tile,
                    );
                    let (pst, pmv) = execute_pooled_recorded(&p, &tsched, &pool, threads);
                    if pst != lst {
                        return Err(format!("{v:?}: threads={threads} tile={tile} table"));
                    }
                    for idx in 0..want_st.len() {
                        let w = want_mv.get(idx);
                        if fmv.get(idx) != w || lmv.get(idx) != w || pmv.get(idx) != w {
                            return Err(format!(
                                "{v:?}: threads={threads} tile={tile} move mismatch at {idx}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_recorded_agrees_with_solve() {
        let mut rng = crate::util::rng::Rng::seeded(97);
        for v in AlignVariant::ALL {
            let p = AlignProblem::random(&mut rng, 10..40, 4, v);
            let (st, _) = solve_recorded(&p);
            assert_eq!(st, solve(&p), "{v:?}");
            let (pst, pmoves) = solve_pooled_recorded(&p);
            assert_eq!(pst, solve(&p), "{v:?}");
            let sol = crate::core::traceback::align_solution(&p, &pst, &pmoves);
            assert_eq!(sol.score, seq::score(&p), "{v:?}");
        }
    }

    #[test]
    fn pooled_block_barrier_budget() {
        // one barrier per block-diagonal: ⌈m/B⌉ + ⌈n/B⌉ − 1, itself
        // ≤ ⌈(m + n − 1)/B⌉ — the superstep sync-reduction contract
        let pool = ExecPool::new(3);
        let mut rng = crate::util::rng::Rng::seeded(11);
        for (rows, cols, tile) in [(17usize, 9usize, 4usize), (33, 33, 8), (5, 40, 3)] {
            let a: Vec<i64> = (0..rows).map(|_| rng.range(0..4)).collect();
            let b: Vec<i64> = (0..cols).map(|_| rng.range(0..4)).collect();
            let p = AlignProblem::lcs(a, b).unwrap();
            let sched =
                crate::core::schedule::AlignSchedule::compile_tiled(rows, cols, tile);
            let (st, rounds) = execute_pooled_counted(&p, &sched, &pool, 3);
            assert_eq!(st, seq::solve(&p), "{rows}x{cols} tile={tile}");
            assert_eq!(rounds as usize, sched.num_steps());
            let untiled_steps = rows + cols - 1;
            assert!(
                (rounds as usize) <= untiled_steps.div_ceil(tile),
                "{rows}x{cols} tile={tile}: {rounds} barriers for {untiled_steps} anti-diagonals"
            );
        }
    }

    #[test]
    fn solve_pooled_matches_all_variants() {
        let mut rng = crate::util::rng::Rng::seeded(23);
        for v in AlignVariant::ALL {
            let p = AlignProblem::random(&mut rng, 20..60, 4, v);
            assert_eq!(solve_pooled(&p), seq::solve(&p), "{v:?}");
        }
    }

    #[test]
    fn solve_uses_cached_schedule_and_matches() {
        let p = AlignProblem::lcs(vec![1, 2, 3, 4, 7], vec![2, 3, 9, 4]).unwrap();
        assert_eq!(solve(&p), seq::solve(&p));
        assert_eq!(p.scalar(&solve(&p)), 3); // LCS {2, 3, 4}
        // second solve of the same shape must hit the process-wide cache
        let before = crate::core::cache::global_stats().hits;
        let _ = solve(&p);
        assert!(crate::core::cache::global_stats().hits > before);
    }

    #[test]
    fn local_scoring_respected_by_wavefront() {
        let scoring = AlignScoring {
            match_s: 3,
            mismatch: -2,
            gap: -2,
        };
        let p = AlignProblem::new(
            vec![5, 1, 2, 3, 5],
            vec![8, 1, 2, 3, 8],
            AlignVariant::Local,
            scoring,
        )
        .unwrap();
        assert_eq!(p.scalar(&solve(&p)), 9); // 3 matches × 3
        assert_eq!(solve(&p), seq::solve(&p));
    }

    #[test]
    fn degenerate_single_symbol_grids() {
        for v in AlignVariant::ALL {
            let p =
                AlignProblem::new(vec![4], vec![4], v, AlignScoring::default()).unwrap();
            let sched = crate::core::schedule::AlignSchedule::compile(1, 1);
            assert_eq!(execute(&p, &sched), seq::solve(&p), "{v:?}");
        }
    }

    #[test]
    fn trace_shows_first_antidiagonal() {
        let p = AlignProblem::lcs(vec![1, 2], vec![3, 4]).unwrap();
        let t = trace(&p, 2);
        assert!(t.contains("T[1,1]"), "{t}");
    }

    #[test]
    #[should_panic(expected = "untiled")]
    fn threaded_rejects_tiled_schedules() {
        // the per-step chunked executor's safety argument only holds for
        // cell-level anti-diagonals; blocked schedules must be refused
        let p = AlignProblem::lcs(vec![1, 2, 3, 4], vec![1, 2, 3, 4]).unwrap();
        let sched = crate::core::schedule::AlignSchedule::compile_tiled(4, 4, 2);
        execute_threaded(&p, &sched, 2);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn executor_rejects_mismatched_schedule() {
        let p = AlignProblem::lcs(vec![1, 2], vec![3, 4]).unwrap();
        let sched = crate::core::schedule::AlignSchedule::compile(3, 3);
        execute(&p, &sched);
    }
}
