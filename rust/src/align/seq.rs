//! Classic row-major sequential alignment DP — the oracle every
//! wavefront executor is property-tested against.

use crate::core::problem::{AlignProblem, AlignVariant};
use crate::core::schedule::grid;

/// Solve the full `(m+1)×(n+1)` table row-major.
pub fn solve(p: &AlignProblem) -> Vec<i64> {
    let (m, n) = (p.rows(), p.cols());
    let mut st = p.initial_table();
    for i in 1..=m {
        for j in 1..=n {
            let up = st[grid::cell_index(n, i - 1, j)];
            let left = st[grid::cell_index(n, i, j - 1)];
            let diag = st[grid::cell_index(n, i - 1, j - 1)];
            st[grid::cell_index(n, i, j)] =
                cell(p.variant, &p.scoring, up, left, diag, p.a[i - 1], p.b[j - 1]);
        }
    }
    st
}

/// The variant's scalar answer (LCS length / edit distance / best local
/// score).
pub fn score(p: &AlignProblem) -> i64 {
    p.scalar(&solve(p))
}

/// [`solve`] + per-cell move recording through the shared traceback
/// recurrence ([`crate::core::traceback::cell_move`]) — the sequential
/// oracle the recording wavefront executors are pinned against
/// (DESIGN.md §8).
pub fn solve_with_moves(p: &AlignProblem) -> (Vec<i64>, crate::core::traceback::MoveArena) {
    let (m, n) = (p.rows(), p.cols());
    let mut st = p.initial_table();
    let moves = crate::core::traceback::MoveArena::new(st.len());
    for i in 1..=m {
        for j in 1..=n {
            let (v, code) = crate::core::traceback::cell_move(
                p.variant,
                &p.scoring,
                st[grid::cell_index(n, i - 1, j)],
                st[grid::cell_index(n, i, j - 1)],
                st[grid::cell_index(n, i - 1, j - 1)],
                p.a[i - 1],
                p.b[j - 1],
            );
            st[grid::cell_index(n, i, j)] = v;
            moves.set(grid::cell_index(n, i, j), code);
        }
    }
    (st, moves)
}

/// One cell of the recurrence — shared with the wavefront executors so
/// the oracle and the pipeline cannot drift apart semantically (they
/// differ only in traversal order, which hazard-freedom makes
/// observationally equivalent).
#[inline(always)]
pub(crate) fn cell(
    variant: AlignVariant,
    scoring: &crate::core::problem::AlignScoring,
    up: i64,
    left: i64,
    diag: i64,
    av: i64,
    bv: i64,
) -> i64 {
    match variant {
        AlignVariant::Lcs => {
            if av == bv {
                diag + 1
            } else {
                up.max(left)
            }
        }
        AlignVariant::Edit => {
            let sub = diag + i64::from(av != bv);
            sub.min(up + 1).min(left + 1)
        }
        AlignVariant::Local => {
            let s = if av == bv {
                scoring.match_s
            } else {
                scoring.mismatch
            };
            (diag + s).max(up + scoring.gap).max(left + scoring.gap).max(0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::problem::AlignScoring;

    #[test]
    fn lcs_textbook() {
        // LCS("ABCBDAB", "BDCABA") = 4 (e.g. "BCBA"), encoded as ints
        let a = vec![1, 2, 3, 2, 4, 1, 2]; // A B C B D A B
        let b = vec![2, 4, 3, 1, 2, 1]; // B D C A B A
        let p = AlignProblem::lcs(a, b).unwrap();
        assert_eq!(score(&p), 4);
    }

    #[test]
    fn edit_textbook() {
        // levenshtein("kitten", "sitting") = 3
        let a = vec![10, 8, 19, 19, 4, 13]; // k i t t e n
        let b = vec![18, 8, 19, 19, 8, 13, 6]; // s i t t i n g
        let p = AlignProblem::new(a, b, AlignVariant::Edit, AlignScoring::default()).unwrap();
        assert_eq!(score(&p), 3);
    }

    #[test]
    fn edit_degenerate_cases() {
        // identical sequences: distance 0; disjoint: max(m, n)
        let p = AlignProblem::new(
            vec![1, 2, 3],
            vec![1, 2, 3],
            AlignVariant::Edit,
            AlignScoring::default(),
        )
        .unwrap();
        assert_eq!(score(&p), 0);
        let p = AlignProblem::new(
            vec![1, 1],
            vec![2, 2, 2, 2],
            AlignVariant::Edit,
            AlignScoring::default(),
        )
        .unwrap();
        assert_eq!(score(&p), 4);
    }

    #[test]
    fn local_finds_embedded_match() {
        // a shared run of 3 symbols scores 3·match with default scoring
        let p = AlignProblem::new(
            vec![9, 9, 1, 2, 3, 9],
            vec![7, 1, 2, 3, 7, 7],
            AlignVariant::Local,
            AlignScoring::default(),
        )
        .unwrap();
        assert_eq!(score(&p), 6); // 3 matches × match_s = 2
    }

    #[test]
    fn local_never_negative() {
        let p = AlignProblem::new(
            vec![1, 2, 3],
            vec![4, 5, 6],
            AlignVariant::Local,
            AlignScoring::default(),
        )
        .unwrap();
        assert!(solve(&p).iter().all(|&v| v >= 0));
        assert_eq!(score(&p), 0);
    }

    #[test]
    fn solve_with_moves_table_matches_plain_solve() {
        use crate::prop::forall;
        forall("seq moves table == solve", 60, |g| {
            let mut rng = g.rng().fork();
            let v = *g.choose(&AlignVariant::ALL);
            let p = AlignProblem::random(&mut rng, 1..40, 4, v);
            let (st, moves) = solve_with_moves(&p);
            if st != solve(&p) {
                return Err(format!("{v:?} {}x{} table", p.rows(), p.cols()));
            }
            // recorded moves == from-table recompute (one tie-break)
            let recomputed = crate::core::traceback::align_moves_from_table(&p, &st);
            for idx in 0..st.len() {
                if moves.get(idx) != recomputed.get(idx) {
                    return Err(format!("{v:?}: move mismatch at cell {idx}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lcs_bounded_by_shorter_sequence() {
        use crate::prop::forall;
        forall("lcs bounds", 60, |g| {
            let mut rng = g.rng().fork();
            let p = AlignProblem::random(&mut rng, 1..32, 3, AlignVariant::Lcs);
            let s = score(&p);
            if s >= 0 && s <= p.rows().min(p.cols()) as i64 {
                Ok(())
            } else {
                Err(format!("lcs {s} of {}x{}", p.rows(), p.cols()))
            }
        });
    }
}
