//! A bounded worker thread pool (no tokio offline; condvar-based queue).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<(VecDeque<Job>, bool)>, // (jobs, shutting_down)
    available: Condvar,
}

/// Fixed-size worker pool; jobs are FIFO.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new((VecDeque::new(), false)),
            available: Condvar::new(),
        });
        let handles = (0..workers.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("pipedp-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let mut guard = shared.queue.lock().unwrap();
                            loop {
                                if let Some(job) = guard.0.pop_front() {
                                    break job;
                                }
                                if guard.1 {
                                    return;
                                }
                                guard = shared.available.wait(guard).unwrap();
                            }
                        };
                        job();
                    })
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Enqueue a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let mut guard = self.shared.queue.lock().unwrap();
        if guard.1 {
            return; // shutting down: drop silently (server is exiting)
        }
        guard.0.push_back(Box::new(job));
        drop(guard);
        self.shared.available.notify_one();
    }

    /// Jobs currently queued (not including running ones).
    pub fn backlog(&self) -> usize {
        self.shared.queue.lock().unwrap().0.len()
    }

    /// Finish queued jobs, then stop the workers.
    pub fn shutdown(mut self) {
        {
            let mut guard = self.shared.queue.lock().unwrap();
            guard.1 = true;
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut guard = self.shared.queue.lock().unwrap();
            guard.1 = true;
        }
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = WorkerPool::new(4);
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let b = barrier.clone();
            let d = done.clone();
            pool.submit(move || {
                // deadlocks unless all 4 run at once
                b.wait();
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..10 {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // implicit drop
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn single_worker_is_fifo() {
        let pool = WorkerPool::new(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..20 {
            let o = order.clone();
            pool.submit(move || o.lock().unwrap().push(i));
        }
        pool.shutdown();
        let got = order.lock().unwrap().clone();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }
}
