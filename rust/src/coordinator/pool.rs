//! A bounded worker thread pool (no tokio offline; condvar-based queue).
//!
//! The queue holds at most `capacity` jobs.  `submit` blocks for a free
//! slot — backpressure on the batcher thread, the memory-safety backstop —
//! while the coordinator's admission gate watches `backlog()` against
//! `capacity()` and sheds *before* anything would block (DESIGN.md §2).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Default queue bound (jobs, i.e. dispatched batches), overridable with
/// `PIPEDP_POOL_QUEUE_CAP`.
pub const DEFAULT_QUEUE_CAP: usize = 256;

struct State {
    jobs: VecDeque<Job>,
    shutting_down: bool,
}

struct Shared {
    state: Mutex<State>,
    capacity: usize,
    /// Signalled when a job is pushed; workers wait on it.
    available: Condvar,
    /// Signalled when a job is popped; blocked submitters wait on it.
    space: Condvar,
}

/// Fixed-size worker pool; jobs are FIFO, the queue is bounded.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// Pool with the default (env-overridable) queue bound.
    pub fn new(workers: usize) -> WorkerPool {
        let capacity = std::env::var("PIPEDP_POOL_QUEUE_CAP")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&c| c > 0)
            .unwrap_or(DEFAULT_QUEUE_CAP);
        WorkerPool::with_capacity(workers, capacity)
    }

    pub fn with_capacity(workers: usize, capacity: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                jobs: VecDeque::new(),
                shutting_down: false,
            }),
            capacity: capacity.max(1),
            available: Condvar::new(),
            space: Condvar::new(),
        });
        let handles = (0..workers.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("pipedp-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let mut guard = shared.state.lock().unwrap();
                            loop {
                                if let Some(job) = guard.jobs.pop_front() {
                                    shared.space.notify_one();
                                    break job;
                                }
                                if guard.shutting_down {
                                    return;
                                }
                                guard = shared.available.wait(guard).unwrap();
                            }
                        };
                        // Isolation boundary: a panicking job must not kill
                        // the worker thread — the pool would silently lose
                        // capacity and, at zero workers, wedge the queue.
                        // Reply construction for panicked solves happens one
                        // level up (the batcher's flush closure); this catch
                        // is the backstop that keeps the worker alive.
                        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    })
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool {
            shared,
            handles: Mutex::new(handles),
        }
    }

    /// The queue bound (jobs) — the admission gate's shed threshold.
    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }

    /// Enqueue a job, blocking while the queue is full.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let mut guard = self.shared.state.lock().unwrap();
        while guard.jobs.len() >= self.shared.capacity && !guard.shutting_down {
            guard = self.shared.space.wait(guard).unwrap();
        }
        if guard.shutting_down {
            return; // shutting down: drop silently (server is exiting)
        }
        guard.jobs.push_back(Box::new(job));
        drop(guard);
        self.shared.available.notify_one();
    }

    /// Jobs currently queued (not including running ones).
    pub fn backlog(&self) -> usize {
        self.shared.state.lock().unwrap().jobs.len()
    }

    /// Finish queued jobs, then stop and join the workers.  Idempotent and
    /// callable through an `Arc` (shutdown order is the server's concern).
    pub fn shutdown(&self) {
        {
            let mut guard = self.shared.state.lock().unwrap();
            guard.shutting_down = true;
        }
        self.shared.available.notify_all();
        self.shared.space.notify_all();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    #[test]
    fn executes_all_jobs() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = WorkerPool::new(4);
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            let b = barrier.clone();
            let d = done.clone();
            pool.submit(move || {
                // deadlocks unless all 4 run at once
                b.wait();
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..10 {
                let c = counter.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // implicit drop
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn single_worker_is_fifo() {
        let pool = WorkerPool::new(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        for i in 0..20 {
            let o = order.clone();
            pool.submit(move || o.lock().unwrap().push(i));
        }
        pool.shutdown();
        let got = order.lock().unwrap().clone();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }

    /// Park the single worker on a job that blocks until released, and
    /// wait until the queue is empty again (the worker holds the plug).
    fn plug_worker(pool: &WorkerPool) -> std::sync::mpsc::Sender<()> {
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        pool.submit(move || {
            let _ = release_rx.recv();
        });
        let t0 = Instant::now();
        while pool.backlog() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "worker never started");
            std::thread::yield_now();
        }
        release_tx
    }

    #[test]
    fn backlog_counts_queued_jobs_exactly() {
        let pool = WorkerPool::with_capacity(1, 16);
        assert_eq!(pool.capacity(), 16);
        assert_eq!(pool.backlog(), 0);
        let release = plug_worker(&pool);
        for k in 1..=5 {
            pool.submit(|| {});
            assert_eq!(pool.backlog(), k, "backlog must track each enqueue");
        }
        release.send(()).unwrap();
        pool.shutdown();
        assert_eq!(pool.backlog(), 0, "shutdown drains the queue");
    }

    #[test]
    fn submit_blocks_at_capacity_until_space_frees() {
        let pool = Arc::new(WorkerPool::with_capacity(1, 2));
        let release = plug_worker(&pool);
        pool.submit(|| {});
        pool.submit(|| {});
        assert_eq!(pool.backlog(), 2);
        // a third submit must block until the plug releases
        let submitted = Arc::new(AtomicUsize::new(0));
        let waiter = {
            let pool = pool.clone();
            let submitted = submitted.clone();
            std::thread::spawn(move || {
                pool.submit(|| {});
                submitted.store(1, Ordering::SeqCst);
            })
        };
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(
            submitted.load(Ordering::SeqCst),
            0,
            "submit past capacity must block"
        );
        release.send(()).unwrap();
        waiter.join().unwrap();
        assert_eq!(submitted.load(Ordering::SeqCst), 1);
        pool.shutdown();
    }

    #[test]
    fn workers_survive_panicking_jobs() {
        // one worker: if the panic killed it, the follow-up jobs would
        // never run and shutdown would leave the counter short
        let pool = WorkerPool::new(1);
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..6 {
            let c = counter.clone();
            pool.submit(move || {
                if i % 2 == 0 {
                    panic!("injected job panic");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn env_default_capacity_applies() {
        // no env override in the test environment ⇒ the documented default
        let pool = WorkerPool::new(1);
        assert!(pool.capacity() >= 1);
    }
}
