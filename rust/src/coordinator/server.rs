//! The TCP server (line-delimited JSON) and a blocking client.
//!
//! One thread per connection reads request lines and hands them to the
//! batcher with a per-request reply channel; a per-connection writer
//! thread serializes responses back (so batched completions from worker
//! threads never interleave bytes).  `kind: "stats"` requests are answered
//! inline with a metrics snapshot.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use crate::coordinator::batcher::{Batcher, Policy};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::request::{Request, RequestBody, Response};
use crate::coordinator::router::Router;
use crate::core::schedule::McmVariant;
use crate::runtime::engine::Engine;
use crate::{Error, Result};

/// Server configuration.
pub struct Config {
    pub addr: String,
    pub workers: usize,
    pub policy: Policy,
    /// Serve without artifacts (native backends only).
    pub allow_engineless: bool,
    /// Pre-compile every artifact in the background at startup so the
    /// first request per bucket does not pay PJRT compilation latency.
    pub warm: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            addr: "127.0.0.1:7070".into(),
            workers: 4,
            policy: Policy::default(),
            allow_engineless: true,
            warm: true,
        }
    }
}

/// A running server (owns the accept thread; `shutdown` is cooperative).
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    warmed: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving in background threads.
    pub fn start(cfg: Config) -> Result<Server> {
        let engine = match Engine::load() {
            Ok(e) => Some(Arc::new(e)),
            Err(e) if cfg.allow_engineless => {
                eprintln!("pipedp-server: running without XLA engine: {e}");
                None
            }
            Err(e) => return Err(e),
        };
        let warmed = Arc::new(AtomicBool::new(!cfg.warm || engine.is_none()));
        if cfg.warm {
            if let Some(engine) = engine.clone() {
                let warmed = warmed.clone();
                std::thread::Builder::new()
                    .name("pipedp-warmup".into())
                    .spawn(move || {
                        let n = engine.warm_all();
                        // Pre-warm the process-wide schedule cache for every
                        // schedule-executor bucket so the first pipeline
                        // request per size pays neither PJRT compile nor
                        // schedule compile latency.  Ascending by n, and
                        // skipping sizes whose term count exceeds the cache
                        // budget: warming those would either thrash the
                        // smaller entries or never stick at all.
                        let cache_stats = crate::core::cache::global_stats();
                        let budget = cache_stats.term_budget;
                        let max_entries = cache_stats.capacity;
                        let mut sizes: Vec<usize> = engine
                            .registry
                            .artifacts
                            .iter()
                            .filter(|s| s.sched_steps > 0)
                            .map(|s| s.n)
                            .collect();
                        sizes.sort_unstable();
                        sizes.dedup();
                        let mut scheds = 0usize;
                        let mut warmed_terms = 0usize;
                        for n in sizes {
                            let terms = (n * n * n - n) / 6; // Σ d·(n−d), per variant
                            // stop once the *cumulative* warmed footprint
                            // would exceed either cache limit — warming
                            // past them would evict the smaller schedules
                            // just warmed
                            if warmed_terms + 2 * terms > budget || scheds + 2 > max_entries {
                                break;
                            }
                            for variant in
                                [McmVariant::PaperFaithful, McmVariant::Corrected]
                            {
                                crate::core::cache::mcm_schedule(n, variant);
                                scheds += 1;
                            }
                            warmed_terms += 2 * terms;
                        }
                        warmed.store(true, Ordering::Release);
                        eprintln!(
                            "pipedp-server: warmed {n} executables, {scheds} schedules"
                        );
                    })
                    .expect("spawn warmup");
            }
        }
        let router = Arc::new(Router::new(engine));
        let pool = Arc::new(WorkerPool::new(cfg.workers));
        let metrics = Arc::new(Metrics::default());
        let batcher = Arc::new(Batcher::start(
            router,
            pool,
            metrics.clone(),
            cfg.policy.clone(),
        ));

        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));

        let accept_handle = {
            let stop = stop.clone();
            let metrics = metrics.clone();
            std::thread::Builder::new()
                .name("pipedp-accept".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                let batcher = batcher.clone();
                                let metrics = metrics.clone();
                                let stop = stop.clone();
                                std::thread::spawn(move || {
                                    let _ = handle_connection(stream, batcher, metrics, stop);
                                });
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(std::time::Duration::from_millis(5));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn accept thread")
        };

        Ok(Server {
            local_addr,
            metrics,
            stop,
            warmed,
            accept_handle: Some(accept_handle),
        })
    }

    /// Block until warmup finished (immediately true when warmup is off or
    /// no engine is loaded).  Serving deployments call this before opening
    /// the floodgates so no request pays PJRT-compile tail latency.
    pub fn wait_ready(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while !self.warmed.load(Ordering::Acquire) {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        true
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    // responses funnel through one channel so writes never interleave
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let writer_handle = std::thread::spawn(move || {
        while let Ok(resp) = resp_rx.recv() {
            let mut line = resp.encode();
            line.push('\n');
            if writer.write_all(line.as_bytes()).is_err() {
                break;
            }
            let _ = writer.flush();
        }
    });

    for line in reader.lines() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        metrics.requests.fetch_add(1, Ordering::Relaxed);
        match Request::decode(&line) {
            Ok(req) if matches!(req.body, RequestBody::Stats) => {
                let mut resp = Response::ok(req.id, 0, "server:stats".into(), None);
                resp.stats = Some(metrics.snapshot());
                let _ = resp_tx.send(resp);
            }
            // routing happens inside the batcher (it owns the
            // engine-aware router) so grouping matches the destination
            Ok(req) => batcher.submit_request(req, resp_tx.clone()),
            Err(e) => {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let _ = resp_tx.send(Response::err(0, e.to_string()));
            }
        }
    }
    drop(resp_tx);
    let _ = writer_handle.join();
    Ok(())
}

/// Blocking client for the wire protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: i64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// Send one request and wait for its response.
    pub fn call(&mut self, mut req: Request) -> Result<Response> {
        req.id = self.next_id;
        self.next_id += 1;
        let mut line = req.encode();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        let mut resp_line = String::new();
        self.reader.read_line(&mut resp_line)?;
        if resp_line.is_empty() {
            return Err(Error::Server("connection closed".into()));
        }
        Response::decode(resp_line.trim_end())
    }

    /// Send `reqs` pipelined (all writes, then all reads) — how a
    /// throughput-oriented client drives the batcher.
    pub fn call_pipelined(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let n = reqs.len();
        let mut payload = String::new();
        for mut req in reqs {
            req.id = self.next_id;
            self.next_id += 1;
            payload.push_str(&req.encode());
            payload.push('\n');
        }
        self.writer.write_all(payload.as_bytes())?;
        self.writer.flush()?;
        let mut responses = Vec::with_capacity(n);
        for _ in 0..n {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            if line.is_empty() {
                return Err(Error::Server("connection closed mid-batch".into()));
            }
            responses.push(Response::decode(line.trim_end())?);
        }
        // responses may complete out of order across buckets; re-order
        responses.sort_by_key(|r| r.id);
        Ok(responses)
    }
}
