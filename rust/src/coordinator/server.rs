//! The TCP server (line-delimited JSON) and a blocking client.
//!
//! The wire protocol itself (request kinds, fields, reply shapes,
//! `overloaded` shed semantics, id correlation) is specified in
//! `docs/PROTOCOL.md`.
//!
//! One thread per connection reads request lines and hands them to the
//! batcher with a per-request reply sink; a per-connection writer
//! thread serializes responses back (so batched completions from worker
//! threads never interleave bytes).  `kind: "stats"` requests are answered
//! inline with a metrics snapshot.  With [`Config::reactor`] set (Linux),
//! the thread-per-connection front end is replaced by a single epoll
//! event loop ([`crate::coordinator::reactor`]) that owns every socket;
//! both front ends funnel lines through the same [`handle_line`], so
//! replies are byte-identical between the two modes.
//!
//! Every thread the server spawns is tracked: `shutdown` stops the accept
//! loop, unblocks parked connection readers with a socket `shutdown`,
//! drains the batcher's pending groups through the worker pool (so every
//! in-flight request is answered or its reply channel closed), and joins
//! everything — a process embedding the server exits cleanly.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{deliver_terminal, Batcher, Policy, ReplySink};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::request::{ErrorKind, Frame, Request, RequestBody, Response};
use crate::coordinator::router::Router;
use crate::core::schedule::McmVariant;
use crate::runtime::engine::Engine;
use crate::util::json::Json;
use crate::{Error, Result};

/// Server configuration.
pub struct Config {
    pub addr: String,
    pub workers: usize,
    pub policy: Policy,
    /// Serve without artifacts (native backends only).
    pub allow_engineless: bool,
    /// Pre-compile every artifact in the background at startup so the
    /// first request per bucket does not pay PJRT compilation latency.
    pub warm: bool,
    /// Worker-queue bound (jobs); past it the admission gate sheds with a
    /// typed `overloaded` reply.  `0` means `PIPEDP_POOL_QUEUE_CAP` or
    /// the built-in default ([`crate::coordinator::pool::DEFAULT_QUEUE_CAP`]).
    pub queue_cap: usize,
    /// Total parallelism of the persistent DP execution pool
    /// ([`crate::runtime::exec_pool`]) used by pooled native solves.
    /// `0` means `PIPEDP_EXEC_THREADS` or the machine's available
    /// parallelism.  First server in a process wins (the pool is
    /// process-wide).
    pub exec_threads: usize,
    /// Memory admission bound: requests whose estimated solve footprint
    /// (table + solution sidecar) exceeds this many bytes are refused
    /// with a typed `too_large` reply before any allocation.  `0` means
    /// `PIPEDP_MAX_SOLVE_BYTES` or unlimited.
    pub max_solve_bytes: usize,
    /// Slow-loris guard: a connection whose request line stalls partially
    /// written for longer than this many milliseconds is dropped.  Idle
    /// connections (no partial line) are never timed out.  `0` means the
    /// built-in default ([`DEFAULT_LINE_STALL`]).
    pub line_stall_ms: u64,
    /// Serve connections from a single epoll event loop
    /// ([`crate::coordinator::reactor`]) instead of a thread per
    /// connection.  Linux only; elsewhere the flag logs a warning and the
    /// blocking front end is used.  Wire behavior is identical.
    pub reactor: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            addr: "127.0.0.1:7070".into(),
            workers: 4,
            policy: Policy::default(),
            allow_engineless: true,
            warm: true,
            queue_cap: 0,
            exec_threads: 0,
            max_solve_bytes: 0,
            line_stall_ms: 0,
            reactor: false,
        }
    }
}

/// Socket read timeout used as the reader's poll interval: each wake
/// checks the stop flag and the partial-line stall clock.
const READ_POLL: Duration = Duration::from_millis(500);
/// Default partial-line stall bound (see [`Config::line_stall_ms`]).
pub const DEFAULT_LINE_STALL: Duration = Duration::from_secs(10);
/// Socket write timeout: a peer that stops reading cannot park a writer
/// thread in `write_all` forever (the drain in `stop_and_drain` has its
/// own bounded window; this bounds the steady state too).
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Distinguishes this server instance's connection threads in
/// `/proc/self/task` (tests assert drain against the tag; names are
/// capped at 15 bytes on Linux, so the tag stays short).
static SERVER_SEQ: AtomicU64 = AtomicU64::new(0);

/// Live-connection registry: the accept loop records each connection's
/// stream (so `shutdown` can unblock its parked reader) and reader-thread
/// handle (so it can join them).
struct Connections {
    tag: String,
    next_id: AtomicU64,
    streams: Mutex<HashMap<u64, TcpStream>>,
    handles: Mutex<HashMap<u64, std::thread::JoinHandle<()>>>,
    /// Ids whose threads have finished; the accept loop reaps (joins)
    /// these as it goes, so handles do not accumulate for the server's
    /// lifetime under connection churn.
    finished: Mutex<Vec<u64>>,
}

impl Connections {
    /// Join every connection thread that announced completion.  Each join
    /// is near-instant (the thread pushed its id as its last act).
    fn reap_finished(&self) {
        let done: Vec<u64> = std::mem::take(&mut *self.finished.lock().unwrap());
        if done.is_empty() {
            return;
        }
        let mut reaped = Vec::with_capacity(done.len());
        {
            let mut handles = self.handles.lock().unwrap();
            for id in done {
                if let Some(h) = handles.remove(&id) {
                    reaped.push(h);
                }
            }
        }
        for h in reaped {
            let _ = h.join(); // outside the lock: joins must not block registration
        }
    }
}

/// A running server (owns every thread it spawned; `shutdown` drains and
/// joins them all).
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    warmed: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    warm_handle: Option<std::thread::JoinHandle<()>>,
    batcher: Arc<Batcher>,
    pool: Arc<WorkerPool>,
    conns: Arc<Connections>,
    #[cfg(target_os = "linux")]
    reactor: Option<crate::coordinator::reactor::Reactor>,
}

impl Server {
    /// Bind and start serving in background threads.
    pub fn start(cfg: Config) -> Result<Server> {
        // bind first: it is the only fallible step besides engine loading,
        // and every `?` after a thread spawns would leak that thread
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let engine = match Engine::load() {
            Ok(e) => Some(Arc::new(e)),
            Err(e) if cfg.allow_engineless => {
                eprintln!("pipedp-server: running without XLA engine: {e}");
                None
            }
            Err(e) => return Err(e),
        };
        let stop = Arc::new(AtomicBool::new(false));
        // the process-wide persistent execution pool for pooled native
        // solves; sized here (first server wins) so warmup calibration
        // and serving use the same parallelism
        let exec_pool = crate::runtime::exec_pool::global_with_hint(cfg.exec_threads);
        let warmed = Arc::new(AtomicBool::new(!cfg.warm));
        let mut warm_handle = None;
        if cfg.warm {
            let engine_for_warm = engine.clone();
            {
                let warmed = warmed.clone();
                let stop = stop.clone();
                let handle = std::thread::Builder::new()
                    .name("pipedp-warmup".into())
                    .spawn(move || {
                        let mut executables = 0usize;
                        let mut scheds = 0usize;
                        if let Some(engine) = engine_for_warm {
                            // abandon warming between buckets when the
                            // server shuts down — `stop_and_drain` joins
                            // this thread, and a fresh shutdown must not
                            // wait out every remaining PJRT compile
                            executables =
                                engine.warm_all_while(|| !stop.load(Ordering::Relaxed));
                            // Pre-warm the process-wide schedule cache for
                            // every schedule-executor bucket so the first
                            // pipeline request per size pays neither PJRT
                            // compile nor schedule compile latency.
                            // Ascending by n, and skipping sizes whose term
                            // count exceeds the cache budget: warming those
                            // would either thrash the smaller entries or
                            // never stick at all.
                            let cache_stats = crate::core::cache::global_stats();
                            let budget = cache_stats.term_budget;
                            let max_entries = cache_stats.capacity;
                            let mut sizes: Vec<usize> = engine
                                .registry
                                .artifacts
                                .iter()
                                .filter(|s| s.sched_steps > 0)
                                .map(|s| s.n)
                                .collect();
                            sizes.sort_unstable();
                            sizes.dedup();
                            let mut warmed_terms = 0usize;
                            for n in sizes {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                                let terms = (n * n * n - n) / 6; // Σ d·(n−d), per variant
                                // stop once the *cumulative* warmed
                                // footprint would exceed either cache limit
                                // — warming past them would evict the
                                // smaller schedules just warmed
                                if warmed_terms + 2 * terms > budget
                                    || scheds + 2 > max_entries
                                {
                                    break;
                                }
                                for variant in
                                    [McmVariant::PaperFaithful, McmVariant::Corrected]
                                {
                                    crate::core::cache::mcm_schedule(n, variant);
                                    scheds += 1;
                                }
                                warmed_terms += 2 * terms;
                            }
                            // alignment wavefronts for every align bucket
                            // (one schedule serves all variants — keyed by
                            // grid shape only), under the same cumulative
                            // budget
                            let mut grids: Vec<(usize, usize)> = engine
                                .registry
                                .artifacts
                                .iter()
                                .filter(|s| {
                                    s.kind == crate::runtime::registry::Kind::Align
                                })
                                .map(|s| (s.n, s.k))
                                .collect();
                            grids.sort_unstable();
                            grids.dedup();
                            for (rows, cols) in grids {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                                let terms = rows * cols;
                                if warmed_terms + terms > budget
                                    || scheds + 1 > max_entries
                                {
                                    break;
                                }
                                crate::core::cache::align_schedule(rows, cols);
                                scheds += 1;
                                warmed_terms += terms;
                            }
                        }
                        // Calibrate the adaptive executor policy on the
                        // persistent pool (engine or not: the native
                        // executors it arbitrates always exist).  A stale
                        // stop flag aborts between measurements.
                        if !stop.load(Ordering::Relaxed) {
                            crate::core::policy::calibrate_and_install(exec_pool, || {
                                !stop.load(Ordering::Relaxed)
                            });
                        }
                        warmed.store(true, Ordering::Release);
                        eprintln!(
                            "pipedp-server: warmed {executables} executables, {scheds} \
                             schedules; executor policy {}",
                            if crate::core::policy::current().calibrated {
                                "calibrated"
                            } else {
                                "uncalibrated (shutdown during warmup)"
                            }
                        );
                    })
                    .expect("spawn warmup");
                warm_handle = Some(handle);
            }
        }
        let router = Arc::new(Router::new(engine));
        let pool = Arc::new(if cfg.queue_cap > 0 {
            WorkerPool::with_capacity(cfg.workers, cfg.queue_cap)
        } else {
            WorkerPool::new(cfg.workers)
        });
        let metrics = Arc::new(Metrics::default());
        let max_solve_bytes = if cfg.max_solve_bytes > 0 {
            cfg.max_solve_bytes
        } else {
            std::env::var("PIPEDP_MAX_SOLVE_BYTES")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(0)
        };
        let line_stall = if cfg.line_stall_ms > 0 {
            Duration::from_millis(cfg.line_stall_ms)
        } else {
            DEFAULT_LINE_STALL
        };
        let batcher = Arc::new(Batcher::start_with_limit(
            router,
            pool.clone(),
            metrics.clone(),
            cfg.policy.clone(),
            max_solve_bytes,
        ));
        let conns = Arc::new(Connections {
            tag: format!("pd{}-", SERVER_SEQ.fetch_add(1, Ordering::Relaxed)),
            next_id: AtomicU64::new(0),
            streams: Mutex::new(HashMap::new()),
            handles: Mutex::new(HashMap::new()),
            finished: Mutex::new(Vec::new()),
        });

        if cfg.reactor && !cfg!(target_os = "linux") {
            eprintln!(
                "pipedp-server: reactor mode is Linux-only; using blocking threads"
            );
        }
        #[cfg(target_os = "linux")]
        let (accept_handle, reactor) = if cfg.reactor {
            let r = crate::coordinator::reactor::Reactor::start(
                listener,
                batcher.clone(),
                metrics.clone(),
                line_stall,
            )?;
            (None, Some(r))
        } else {
            (
                Some(spawn_accept(
                    listener,
                    stop.clone(),
                    metrics.clone(),
                    batcher.clone(),
                    conns.clone(),
                    line_stall,
                )),
                None,
            )
        };
        #[cfg(not(target_os = "linux"))]
        let accept_handle = Some(spawn_accept(
            listener,
            stop.clone(),
            metrics.clone(),
            batcher.clone(),
            conns.clone(),
            line_stall,
        ));

        Ok(Server {
            local_addr,
            metrics,
            stop,
            warmed,
            accept_handle,
            warm_handle,
            batcher,
            pool,
            conns,
            #[cfg(target_os = "linux")]
            reactor,
        })
    }

    /// Block until warmup finished — executable + schedule pre-compiles
    /// (engine only) *and* executor-policy calibration (always, a few ms
    /// in debug builds to a few hundred ms in release).  Immediately true
    /// only when `warm` is off.  Serving deployments call this before
    /// opening the floodgates so no request pays PJRT-compile tail
    /// latency or runs on an uncalibrated policy.
    pub fn wait_ready(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while !self.warmed.load(Ordering::Acquire) {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        true
    }

    /// The per-instance thread-name prefix of this server's connection
    /// threads (observability hook: tests scan `/proc/self/task` for it
    /// to prove the drain joined everything).
    pub fn thread_tag(&self) -> &str {
        &self.conns.tag
    }

    /// Stop accepting, unblock and join every connection thread, flush
    /// in-flight batches, and join the batcher + workers.  After this
    /// returns, no thread the server spawned is alive.
    pub fn shutdown(mut self) {
        self.stop_and_drain();
    }

    fn stop_and_drain(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // 1. stop accepting (joining first means the registry below is
        //    complete: no connection can be mid-registration)
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // 2. unblock every parked connection reader; their `lines()` sees
        //    EOF and each reader drops its reply sender.  Read half only:
        //    the write half stays open so replies to requests drained in
        //    steps 3–4 still reach the client before the sockets close
        //    (they close — and send FIN — when the joined threads drop
        //    their stream handles)
        {
            let streams = self.conns.streams.lock().unwrap();
            for s in streams.values() {
                let _ = s.shutdown(std::net::Shutdown::Read);
            }
        }
        // 3. drain the batcher: every pending group flushes into the pool
        self.batcher.shutdown();
        // 4. run the queued flushes so in-flight requests are answered;
        //    the last reply sender drops here, releasing writer threads
        self.pool.shutdown();
        // 4a. reactor mode: every in-flight reply is now queued on the
        //     reactor's completion channel; stop the loop — it flushes
        //     buffered replies within a bounded window and closes every
        //     socket before its thread joins
        #[cfg(target_os = "linux")]
        if let Some(r) = self.reactor.take() {
            r.stop_and_join();
        }
        // 4b. bounded delivery window: after step 4 every reply sender is
        //     dropped, so each writer thread drains its channel onto the
        //     wire and exits — and its connection thread then removes its
        //     stream from the registry.  Wait for that (bounded) so the
        //     replies the drain just computed actually reach clients.
        let drain_deadline =
            std::time::Instant::now() + std::time::Duration::from_secs(2);
        while std::time::Instant::now() < drain_deadline {
            if self.conns.streams.lock().unwrap().is_empty() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // 4c. force-close both halves of whatever remains: a peer that
        //     stopped *reading* must not park a writer in `write_all`
        //     past the window and hang the joins below (data already in
        //     the kernel send buffer still flushes after FIN)
        {
            let streams = self.conns.streams.lock().unwrap();
            for s in streams.values() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        // 5. join the connection threads (each joins its own writer)
        let handles: Vec<_> = {
            let mut map = self.conns.handles.lock().unwrap();
            map.drain().map(|(_, h)| h).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        // 6. the warmup thread finishes on its own; wait for it
        if let Some(h) = self.warm_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_drain();
    }
}

/// Best-effort id recovery from a line `Request::decode` rejected, so the
/// error reply stays correlatable.  The seed replied with `id: 0`, which
/// a pipelined client cannot match to any request — and which collides
/// with a real `id: 0` request.
fn extract_request_id(line: &str) -> i64 {
    // the line may be valid JSON that is merely an invalid request
    if let Ok(v) = Json::parse(line) {
        if let Ok(id) = v.i64_field("id") {
            return id;
        }
    }
    // Not valid JSON: scan for a *top-level* `"id"` key — brace depth 1,
    // outside strings, in key position (preceded by `{` or `,`) — so
    // neither an `"id"` nested in a sub-object nor a string *value* that
    // happens to be `id` can be mistaken for (and collide with) another
    // live request's id.
    let bytes = line.as_bytes();
    let mut depth = 0i32;
    let mut in_str = false;
    let mut prev = 0u8; // last non-space byte seen outside strings
    let mut i = 0usize;
    while i < bytes.len() {
        let c = bytes[i];
        if in_str {
            match c {
                b'\\' => i += 1, // skip the escaped byte
                b'"' => in_str = false,
                _ => {}
            }
        } else {
            match c {
                b'{' | b'[' => depth += 1,
                b'}' | b']' => depth -= 1,
                b'"' => {
                    if depth == 1
                        && (prev == b'{' || prev == b',')
                        && line[i..].starts_with("\"id\"")
                    {
                        if let Some(id) = parse_int_after(line, i + 4) {
                            return id;
                        }
                    }
                    in_str = true;
                }
                _ => {}
            }
            if !is_json_ws(c) {
                prev = c;
            }
        }
        i += 1;
    }
    0
}

/// JSON insignificant whitespace (RFC 8259 §2; `\n` cannot occur in a
/// line-delimited request but costs nothing to accept).
fn is_json_ws(b: u8) -> bool {
    matches!(b, b' ' | b'\t' | b'\r' | b'\n')
}

/// Parse the integer in `": <int>"` at `i`; `None` when the colon or the
/// digits are missing (the caller keeps scanning).
fn parse_int_after(line: &str, mut i: usize) -> Option<i64> {
    let bytes = line.as_bytes();
    while i < bytes.len() && is_json_ws(bytes[i]) {
        i += 1;
    }
    if i >= bytes.len() || bytes[i] != b':' {
        return None;
    }
    i += 1;
    while i < bytes.len() && is_json_ws(bytes[i]) {
        i += 1;
    }
    let start = i;
    if i < bytes.len() && bytes[i] == b'-' {
        i += 1;
    }
    while i < bytes.len() && bytes[i].is_ascii_digit() {
        i += 1;
    }
    line[start..i].parse::<i64>().ok()
}

/// The blocking front end: accept connections and spawn a
/// reader + writer thread pair per connection, every thread registered
/// with `conns` so `stop_and_drain` can unblock and join them.
fn spawn_accept(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
    batcher: Arc<Batcher>,
    conns: Arc<Connections>,
    line_stall: Duration,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("pipedp-accept".into())
        .spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                // join threads of connections that already ended so
                // handles do not accumulate for the server lifetime
                conns.reap_finished();
                match listener.accept() {
                    Ok((stream, _)) => {
                        let id = conns.next_id.fetch_add(1, Ordering::Relaxed);
                        // registered *before* the reader spawns so
                        // `shutdown` (which joins this accept thread
                        // first) can always unblock it; a connection
                        // whose stream cannot be cloned (fd pressure)
                        // is dropped rather than parked un-unblockable
                        match stream.try_clone() {
                            Ok(s) => {
                                conns.streams.lock().unwrap().insert(id, s);
                            }
                            Err(_) => continue,
                        }
                        let batcher = batcher.clone();
                        let metrics = metrics.clone();
                        let stop = stop.clone();
                        let conns2 = conns.clone();
                        let writer_name = format!("{}w{}", conns.tag, id);
                        let handle = std::thread::Builder::new()
                            .name(format!("{}c{}", conns.tag, id))
                            .spawn(move || {
                                let _ = handle_connection(
                                    stream,
                                    batcher,
                                    metrics,
                                    stop,
                                    writer_name,
                                    line_stall,
                                );
                                conns2.streams.lock().unwrap().remove(&id);
                                // last act: announce completion for
                                // the accept loop's reaper
                                conns2.finished.lock().unwrap().push(id);
                            })
                            .expect("spawn connection thread");
                        conns.handles.lock().unwrap().insert(id, handle);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        })
        .expect("spawn accept thread")
}

/// Decode one request line and dispatch it: `stats` answered inline with
/// a metrics snapshot, decode errors answered with a typed error reply
/// correlated via [`extract_request_id`], everything else submitted to
/// the batcher with the given reply sink.  Both front ends — the
/// thread-per-connection reader and the epoll reactor — funnel every
/// non-empty line through here, which is what keeps their wire behavior
/// byte-identical.
pub(crate) fn handle_line(line: &str, batcher: &Batcher, metrics: &Metrics, reply: ReplySink) {
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    match Request::decode(line) {
        Ok(req) if matches!(req.body, RequestBody::Stats) => {
            let mut resp = Response::ok(req.id, 0, "server:stats".into(), None);
            resp.stats = Some(metrics.snapshot());
            deliver_terminal(&reply, req.stream, resp);
        }
        // routing happens inside the batcher (it owns the engine-aware
        // router) so grouping matches the destination
        Ok(req) => batcher.submit_request(req, reply),
        Err(e) => {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
            let resp = Response::err(extract_request_id(line), e.to_string());
            deliver_terminal(&reply, false, resp);
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    writer_name: String,
    line_stall: Duration,
) -> Result<()> {
    // the read timeout turns the reader into a poll loop (stop flag +
    // stall clock); the write timeout keeps a non-reading peer from
    // parking the writer thread in write_all indefinitely
    stream.set_read_timeout(Some(READ_POLL))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    // replies funnel through one channel of pre-encoded lines so writes
    // never interleave; carrying lines (not `Response`s) lets streaming
    // progress/solution frames share the path with unary replies
    let (resp_tx, resp_rx) = mpsc::channel::<String>();
    let writer_handle = std::thread::Builder::new()
        .name(writer_name)
        .spawn(move || {
            while let Ok(mut line) = resp_rx.recv() {
                line.push('\n');
                if writer.write_all(line.as_bytes()).is_err() {
                    break;
                }
                let _ = writer.flush();
            }
        })
        .expect("spawn connection writer");

    // Manual line loop instead of `lines()`: a timed-out read keeps its
    // partial bytes in `line`, so an *idle* connection (empty buffer)
    // lives forever while a line trickling in slower than `line_stall`
    // (slow loris) gets the connection dropped.
    let mut line = String::new();
    let mut line_started: Option<Instant> = None;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF (or the drain's socket shutdown)
            Ok(_) => {
                line_started = None;
                if line.trim().is_empty() {
                    line.clear();
                    continue;
                }
                handle_line(&line, &batcher, &metrics, ReplySink::Line(resp_tx.clone()));
                line.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if line.is_empty() {
                    line_started = None; // idle between requests: no clock
                } else {
                    let t0 = *line_started.get_or_insert_with(Instant::now);
                    if t0.elapsed() >= line_stall {
                        break; // partial line stalled too long: drop
                    }
                }
            }
            Err(_) => break, // socket shut down mid-read: drain and exit
        }
    }
    drop(resp_tx);
    let _ = writer_handle.join();
    Ok(())
}

/// Blocking client for the wire protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: i64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// [`Client::connect`] that cannot hang: the dial is bounded by
    /// `connect` (per resolved address), and `read` (if set) bounds every
    /// reply wait — a server that accepts but never answers surfaces as
    /// a typed `timeout` error from [`Client::call`] instead of blocking
    /// the caller forever.
    pub fn connect_with_timeout(
        addr: &str,
        connect: Duration,
        read: Option<Duration>,
    ) -> Result<Client> {
        use std::net::ToSocketAddrs;
        let mut last_err: Option<std::io::Error> = None;
        let mut stream = None;
        for a in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&a, connect) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let stream = match (stream, last_err) {
            (Some(s), _) => s,
            (None, Some(e)) => return Err(e.into()),
            (None, None) => {
                return Err(Error::Server(format!("'{addr}' resolved to no address")))
            }
        };
        stream.set_read_timeout(read)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// Read one reply line, surfacing a read-timeout as a typed
    /// [`Error::Timeout`] (only possible when the client was built with
    /// a read timeout) and EOF as a connection-closed server error.
    fn read_reply_line(&mut self) -> Result<String> {
        let mut resp_line = String::new();
        if let Err(e) = self.reader.read_line(&mut resp_line) {
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) {
                return Err(Error::Timeout(
                    "no reply within the client read timeout".into(),
                ));
            }
            return Err(e.into());
        }
        if resp_line.is_empty() {
            return Err(Error::Server("connection closed".into()));
        }
        Ok(resp_line)
    }

    /// Send one request and wait for its response.
    pub fn call(&mut self, mut req: Request) -> Result<Response> {
        req.id = self.next_id;
        self.next_id += 1;
        let mut line = req.encode();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        let resp_line = self.read_reply_line()?;
        Response::decode(resp_line.trim_end())
    }

    /// [`Client::call`] with bounded, jittered-backoff retry on typed
    /// `overloaded` sheds (docs/PROTOCOL.md retry guidance).  At most
    /// `max_retries` re-sends; every other reply — success, `timeout`,
    /// `too_large`, `panicked`, plain errors — returns immediately.
    /// `max_retries = 0` behaves exactly like [`Client::call`].
    pub fn call_with_retry(&mut self, req: Request, max_retries: u32) -> Result<Response> {
        let mut rng = crate::util::rng::Rng::seeded(0x9e37_79b9 ^ self.next_id as u64);
        let mut attempt = 0u32;
        loop {
            let resp = self.call(req.clone())?;
            if resp.error_kind != Some(ErrorKind::Overloaded) || attempt >= max_retries {
                return Ok(resp);
            }
            // exponential base with full jitter: 1–2, 2–4, 4–8 … ms,
            // capped so a long retry budget cannot stall a caller
            let base = 1u64 << attempt.min(6);
            let jitter = rng.range(0..(base as i64 + 1)) as u64;
            std::thread::sleep(Duration::from_millis(base + jitter));
            attempt += 1;
        }
    }

    /// Send one request with `stream: true` and consume its frame
    /// sequence (docs/PROTOCOL.md "Streaming"): each `progress` frame
    /// invokes `on_progress(supersteps, cells)`, `solution` chunks are
    /// reassembled in arrival order, and the terminal `result` frame is
    /// returned with the reassembled solution re-attached — so callers
    /// see exactly what the unary [`Client::call`] would have returned.
    /// A server that ignores the flag (or refuses the request) simply
    /// yields zero progress frames before the result.
    pub fn call_streaming(
        &mut self,
        mut req: Request,
        mut on_progress: impl FnMut(u64, u64),
    ) -> Result<Response> {
        req.id = self.next_id;
        self.next_id += 1;
        req.stream = true;
        let id = req.id;
        let mut line = req.encode();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        let mut chunks = String::new();
        loop {
            let resp_line = self.read_reply_line()?;
            match Frame::decode(resp_line.trim_end())? {
                Frame::Progress {
                    id: fid,
                    supersteps,
                    cells,
                } => {
                    if fid == id {
                        on_progress(supersteps, cells);
                    }
                }
                Frame::SolutionChunk { id: fid, chunk, .. } => {
                    if fid == id {
                        chunks.push_str(&chunk);
                    }
                }
                Frame::Result(mut resp) => {
                    if resp.id != id {
                        continue; // stray reply from earlier traffic
                    }
                    if resp.solution.is_none() && !chunks.is_empty() {
                        resp.solution = Some(Json::parse(&chunks)?);
                    }
                    return Ok(resp);
                }
            }
        }
    }

    /// Send `reqs` pipelined (all writes, then all reads) — how a
    /// throughput-oriented client drives the batcher.
    ///
    /// Responses whose id matches a request from this batch are returned
    /// sorted by id; replies the server could not correlate (an error
    /// reply whose id could not be recovered from a malformed line) are
    /// appended after them in arrival order instead of corrupting the
    /// sorted prefix.
    pub fn call_pipelined(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let n = reqs.len();
        let first_id = self.next_id;
        let mut payload = String::new();
        for mut req in reqs {
            req.id = self.next_id;
            self.next_id += 1;
            payload.push_str(&req.encode());
            payload.push('\n');
        }
        let sent_ids = first_id..self.next_id;
        self.writer.write_all(payload.as_bytes())?;
        self.writer.flush()?;
        let mut matched = Vec::with_capacity(n);
        let mut orphans = Vec::new();
        for _ in 0..n {
            let line = self.read_reply_line()?;
            let resp = Response::decode(line.trim_end())?;
            if sent_ids.contains(&resp.id) {
                matched.push(resp);
            } else {
                orphans.push(resp);
            }
        }
        // responses may complete out of order across buckets; re-order
        matched.sort_by_key(|r| r.id);
        matched.extend(orphans);
        Ok(matched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_recovery_from_broken_lines() {
        // valid JSON, invalid request (missing kind)
        assert_eq!(extract_request_id(r#"{"id": 42}"#), 42);
        // invalid JSON with a recoverable id
        assert_eq!(extract_request_id(r#"{"id": 37, "kind": "sdp", BROKEN"#), 37);
        assert_eq!(extract_request_id(r#"{"id":-5,"kind":1}"#), -5);
        // the top-level id is found even after a nested object
        assert_eq!(extract_request_id(r#"{"a":{"x":1},"id": 9, BROKEN"#), 9);
        // a string *value* of "id" is not the key; the real key after it
        // is still recovered
        assert_eq!(extract_request_id(r#"{"kind": "id", "id": 37, BROKEN"#), 37);
        // tabs are JSON whitespace too
        assert_eq!(extract_request_id("{\t\"id\"\t: 21, BROKEN"), 21);
        // nothing to recover
        assert_eq!(extract_request_id("not json at all"), 0);
        assert_eq!(extract_request_id(r#"{"id": "seven"}"#), 0);
        assert_eq!(extract_request_id(""), 0);
        // a *nested* "id" must never be recovered: it could collide with
        // a different live request on the same connection
        assert_eq!(extract_request_id(r#"{"kind":"mcm","problem":{"id":3,"#), 0);
        assert_eq!(extract_request_id(r#"{"dims":[1,2],"meta":{"id":7}"#), 0);
        // an "id" inside a string value is not a key
        assert_eq!(extract_request_id(r#"{"note":"the \"id\" is 8", BROKEN"#), 0);
    }
}
