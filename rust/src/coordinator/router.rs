//! Backend routing and request execution.
//!
//! The router decides, per request, whether to serve from the native Rust
//! executors or from an AOT XLA bucket (honouring an explicit `backend`
//! if the request pinned one), and executes single requests or batched
//! groups against the chosen backend.

use std::sync::Arc;

use crate::coordinator::request::{Backend, Request, RequestBody, Response};
use crate::core::problem::{McmProblem, SdpProblem};
use crate::core::schedule::McmVariant;
use crate::runtime::engine::Engine;
use crate::{Error, Result};

/// Instances at or below these sizes are cheaper natively than through a
/// PJRT dispatch (measured in `bench xla_engine`; see EXPERIMENTS.md §Perf).
pub const NATIVE_SDP_CUTOFF: usize = 64;
pub const NATIVE_MCM_CUTOFF: usize = 8;

/// Resolved routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    Native,
    Xla,
}

/// The router: owns the engine (if artifacts are available).
pub struct Router {
    pub engine: Option<Arc<Engine>>,
}

impl Router {
    pub fn new(engine: Option<Arc<Engine>>) -> Router {
        Router { engine }
    }

    /// Decide where a request should run.
    pub fn route(&self, req: &Request) -> Result<Route> {
        let fits_xla = |req: &Request| -> bool {
            let Some(engine) = &self.engine else {
                return false;
            };
            match &req.body {
                RequestBody::Sdp(p) => engine.registry.route_sdp(p.n, p.k(), p.op, 1).is_some(),
                RequestBody::Mcm { problem, variant } => match variant {
                    McmVariant::Corrected => {
                        engine.registry.route_mcm(problem.n(), "diagonal", 1).is_some()
                    }
                    // faithful semantics exist only in the schedule executor
                    McmVariant::PaperFaithful => engine
                        .registry
                        .artifacts
                        .iter()
                        .any(|a| a.algo == "pipeline" && a.n == problem.n()),
                },
                RequestBody::Stats => false,
            }
        };
        match req.backend {
            Backend::Native => Ok(Route::Native),
            Backend::Xla => {
                if fits_xla(req) {
                    Ok(Route::Xla)
                } else {
                    Err(Error::Runtime(
                        "no XLA artifact bucket fits this request".into(),
                    ))
                }
            }
            Backend::Auto => {
                let small = match &req.body {
                    RequestBody::Sdp(p) => p.n <= NATIVE_SDP_CUTOFF,
                    RequestBody::Mcm { problem, .. } => problem.n() <= NATIVE_MCM_CUTOFF,
                    RequestBody::Stats => true,
                };
                if !small && fits_xla(req) {
                    Ok(Route::Xla)
                } else {
                    Ok(Route::Native)
                }
            }
        }
    }

    /// Execute one request (already routed).
    pub fn execute(&self, req: &Request, route: Route) -> Response {
        let result = match route {
            Route::Native => self.execute_native(req),
            Route::Xla => self.execute_xla(req),
        };
        match result {
            Ok(r) => r,
            Err(e) => Response::err(req.id, e.to_string()),
        }
    }

    fn execute_native(&self, req: &Request) -> Result<Response> {
        match &req.body {
            RequestBody::Sdp(p) => {
                let st = crate::sdp::pipeline::solve(p);
                Ok(self.done(req, st, "native:sdp_pipeline"))
            }
            RequestBody::Mcm { problem, variant } => {
                let st = crate::mcm::pipeline::solve(problem, *variant);
                Ok(self.done(req, st, &format!("native:mcm_pipeline_{}", variant.name())))
            }
            RequestBody::Stats => Err(Error::Server("stats handled by server".into())),
        }
    }

    fn execute_xla(&self, req: &Request) -> Result<Response> {
        let engine = self
            .engine
            .as_ref()
            .ok_or_else(|| Error::Runtime("engine unavailable".into()))?;
        match &req.body {
            RequestBody::Sdp(p) => {
                let st = engine.solve_sdp(p)?;
                Ok(self.done(req, st, "xla:sdp_pipeline"))
            }
            RequestBody::Mcm { problem, variant } => {
                let st = match variant {
                    McmVariant::Corrected => engine.solve_mcm(problem)?,
                    McmVariant::PaperFaithful => {
                        engine.solve_mcm_pipeline(problem, McmVariant::PaperFaithful)?
                    }
                };
                Ok(self.done(req, st, "xla:mcm"))
            }
            RequestBody::Stats => Err(Error::Server("stats handled by server".into())),
        }
    }

    /// Execute a group of same-bucket requests, batched when a batch
    /// artifact exists; falls back to per-request execution.
    pub fn execute_group(&self, reqs: &[Request], route: Route) -> Vec<Response> {
        if route == Route::Xla && reqs.len() > 1 {
            if let Some(responses) = self.try_execute_batched(reqs) {
                return responses;
            }
        }
        reqs.iter().map(|r| self.execute(r, route)).collect()
    }

    fn try_execute_batched(&self, reqs: &[Request]) -> Option<Vec<Response>> {
        let engine = self.engine.as_ref()?;
        // homogeneous-kind groups only (the batcher's key guarantees this)
        match &reqs[0].body {
            RequestBody::Sdp(_) => {
                let ps: Vec<&SdpProblem> = reqs
                    .iter()
                    .map(|r| match &r.body {
                        RequestBody::Sdp(p) => p,
                        _ => unreachable!("batch key mixes kinds"),
                    })
                    .collect();
                let first = ps[0];
                engine.registry.route_sdp(first.n, first.k(), first.op, ps.len())?;
                let tables = engine.solve_sdp_batch(&ps).ok()?;
                Some(
                    reqs.iter()
                        .zip(tables)
                        .map(|(r, st)| self.done(r, st, "xla:sdp_pipeline[batched]"))
                        .collect(),
                )
            }
            RequestBody::Mcm { .. } => {
                let ps: Vec<&McmProblem> = reqs
                    .iter()
                    .map(|r| match &r.body {
                        RequestBody::Mcm { problem, .. } => problem,
                        _ => unreachable!("batch key mixes kinds"),
                    })
                    .collect();
                let n_max = ps.iter().map(|p| p.n()).max()?;
                engine.registry.route_mcm(n_max, "diagonal", ps.len())?;
                let tables = engine.solve_mcm_batch(&ps).ok()?;
                Some(
                    reqs.iter()
                        .zip(tables)
                        .map(|(r, st)| self.done(r, st, "xla:mcm_diagonal[batched]"))
                        .collect(),
                )
            }
            RequestBody::Stats => None,
        }
    }

    fn done(&self, req: &Request, table: Vec<i64>, served_by: &str) -> Response {
        let value = *table.last().unwrap_or(&0);
        Response::ok(
            req.id,
            value,
            served_by.to_string(),
            if req.full { Some(table) } else { None },
        )
    }
}

/// Batching key: requests with equal keys can share one dispatch.
///
/// `Ord` exists for the batcher's deadline heap (`Reverse<(Instant,
/// GroupKey)>` entries need a total order); the ordering itself carries
/// no meaning.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GroupKey {
    Sdp {
        n: usize,
        k: usize,
        op: &'static str,
    },
    Mcm {
        n: usize,
        variant: &'static str,
    },
    Single(i64),
}

/// Compute the batching key for a routed request; `Single` keys are never
/// merged (stats, native routes get trivially unique keys).
pub fn group_key(req: &Request, route: Route) -> GroupKey {
    if route != Route::Xla {
        return GroupKey::Single(req.id);
    }
    match &req.body {
        RequestBody::Sdp(p) => GroupKey::Sdp {
            n: p.n,
            k: p.k(),
            op: p.op.name(),
        },
        RequestBody::Mcm { problem, variant } => GroupKey::Mcm {
            n: problem.n(),
            variant: variant.name(),
        },
        RequestBody::Stats => GroupKey::Single(req.id),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::semigroup::Op;

    fn sdp_req(id: i64, n: usize, backend: Backend) -> Request {
        Request {
            id,
            body: RequestBody::Sdp(
                SdpProblem::new(n, vec![2, 1], Op::Min, vec![5, 3]).unwrap(),
            ),
            backend,
            full: false,
        }
    }

    #[test]
    fn engineless_router_always_native() {
        let r = Router::new(None);
        assert_eq!(r.route(&sdp_req(1, 1000, Backend::Auto)).unwrap(), Route::Native);
        assert!(r.route(&sdp_req(1, 1000, Backend::Xla)).is_err());
    }

    #[test]
    fn native_execution_solves() {
        let r = Router::new(None);
        let mut req = sdp_req(1, 16, Backend::Native);
        req.body = RequestBody::Sdp(SdpProblem::fibonacci(16));
        req.full = true;
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok);
        assert_eq!(resp.value, 987);
        assert_eq!(resp.table.unwrap().len(), 16);
    }

    #[test]
    fn mcm_native_execution() {
        let r = Router::new(None);
        let req = Request {
            id: 2,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::Corrected,
            },
            backend: Backend::Native,
            full: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok);
        assert_eq!(resp.value, 15125);
    }

    #[test]
    fn faithful_variant_served_and_marked() {
        let r = Router::new(None);
        let req = Request {
            id: 3,
            body: RequestBody::Mcm {
                problem: McmProblem::hazard_counterexample(),
                variant: McmVariant::PaperFaithful,
            },
            backend: Backend::Native,
            full: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok);
        assert!(resp.served_by.contains("faithful"));
        // the published schedule overestimates this instance
        let truth = crate::mcm::seq::cost(&McmProblem::hazard_counterexample());
        assert!(resp.value > truth);
    }

    #[test]
    fn group_keys_merge_only_same_bucket() {
        let a = sdp_req(1, 100, Backend::Auto);
        let b = sdp_req(2, 100, Backend::Auto);
        let c = sdp_req(3, 200, Backend::Auto);
        assert_eq!(group_key(&a, Route::Xla), group_key(&b, Route::Xla));
        assert_ne!(group_key(&a, Route::Xla), group_key(&c, Route::Xla));
        // native routes never merge
        assert_ne!(group_key(&a, Route::Native), group_key(&b, Route::Native));
    }
}
