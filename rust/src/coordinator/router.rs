//! Backend routing and request execution.
//!
//! The router decides, per request, whether to serve from the native Rust
//! executors or from an AOT XLA bucket (honouring an explicit `backend`
//! if the request pinned one), and executes single requests or batched
//! groups against the chosen backend.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::request::{Backend, Request, RequestBody, Response};
use crate::core::certify;
use crate::core::faults;
use crate::core::policy::{self, ExecutorChoice, Workload};
use crate::core::problem::{AlignProblem, CykProblem, McmProblem, SdpProblem};
use crate::core::schedule::{default_align_tile, default_mcm_tile, linear, McmVariant};
use crate::core::traceback;
use crate::runtime::engine::Engine;
use crate::runtime::exec_pool::{CancelToken, Progress};
use crate::util::json::Json;
use crate::{Error, Result};

/// Per-request execution controls threaded from the batcher: the absolute
/// deadline derived from `deadline_ms`, and the progress observer of a
/// streamed request (docs/PROTOCOL.md §Streaming).  Both optional; the
/// default is the plain PR-2 execution path.
#[derive(Clone, Default)]
pub struct SolveControls {
    pub deadline: Option<Instant>,
    pub progress: Option<Arc<Progress>>,
}

/// The wire shape of an MCM solution (docs/PROTOCOL.md).
fn mcm_solution_json(parens: &str) -> Json {
    Json::obj(vec![("parens", Json::str(parens))])
}

/// The scalar answer of a solved Viterbi lattice: the best last-column
/// log-probability (the same max [`traceback::viterbi_path`] starts its
/// walk from).
fn viterbi_score(num_states: usize, table: &[f64]) -> f64 {
    let s = num_states.max(1);
    table[table.len() - s..]
        .iter()
        .fold(f64::NEG_INFINITY, |a, &b| if b > a { b } else { a })
}

/// The scalar answer of a solved CYK table: the start symbol's slot at
/// the whole-sentence span (`−∞` means unparseable, not an error).
fn cyk_score(p: &CykProblem, table: &[f64]) -> f64 {
    table[linear::cell_index(p.n(), 0, p.n() - 1) * p.num_nonterminals]
}

/// Streamed solves need an executor with cancellation poll sites — that
/// is where the progress observer samples.  `seq` has none (its only poll
/// is the entry gate), so streaming remaps it to the fused pipeline,
/// which answers identically (oracle parity across executors is
/// property-tested per kind).  Non-streamed requests keep the policy's
/// choice untouched.
fn pollable_choice(choice: ExecutorChoice, streaming: bool) -> ExecutorChoice {
    if streaming && choice == ExecutorChoice::Seq {
        ExecutorChoice::Fused
    } else {
        choice
    }
}

/// Typed refusal for traceback on the faithful schedule: its stale-read
/// argmins do not describe any optimal solution (DESIGN.md §8).
fn faithful_solution_error() -> Error {
    Error::InvalidProblem(
        "solution reconstruction requires the corrected variant; the faithful \
         schedule's stale reads make its argmins meaningless"
            .into(),
    )
}

/// Instances at or below these sizes are cheaper natively than through a
/// PJRT dispatch (measured in `bench xla_engine`; see EXPERIMENTS.md §Perf).
pub const NATIVE_SDP_CUTOFF: usize = 64;
pub const NATIVE_MCM_CUTOFF: usize = 8;
/// Alignment grids with both sides at or below this stay native (the
/// wavefront sweep is O(mn) with a tiny constant; a 128×128 grid solves
/// in ~the PJRT dispatch overhead alone).
pub const NATIVE_ALIGN_CUTOFF: usize = 128;

/// Resolved routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    Native,
    Xla,
}

/// The router: owns the engine (if artifacts are available).
pub struct Router {
    pub engine: Option<Arc<Engine>>,
}

impl Router {
    pub fn new(engine: Option<Arc<Engine>>) -> Router {
        Router { engine }
    }

    /// Decide where a request should run.
    pub fn route(&self, req: &Request) -> Result<Route> {
        let fits_xla = |req: &Request| -> bool {
            let Some(engine) = &self.engine else {
                return false;
            };
            match &req.body {
                RequestBody::Sdp(p) => engine.registry.route_sdp(p.n, p.k(), p.op, 1).is_some(),
                RequestBody::Mcm { problem, variant } => match variant {
                    McmVariant::Corrected => {
                        engine.registry.route_mcm(problem.n(), "diagonal", 1).is_some()
                    }
                    // faithful semantics exist only in the schedule executor
                    McmVariant::PaperFaithful => engine
                        .registry
                        .artifacts
                        .iter()
                        .any(|a| a.algo == "pipeline" && a.n == problem.n()),
                },
                RequestBody::Align(p) => {
                    engine.registry.route_align(p.rows(), p.cols(), 1).is_some()
                }
                // the log-space families are native-only: no Pallas
                // kernel is lowered for them (DESIGN.md §11)
                RequestBody::Viterbi(_) | RequestBody::Cyk(_) => false,
                RequestBody::Stats => false,
            }
        };
        match req.backend {
            Backend::Native => Ok(Route::Native),
            Backend::Xla => {
                if fits_xla(req) {
                    Ok(Route::Xla)
                } else {
                    Err(Error::Runtime(
                        "no XLA artifact bucket fits this request".into(),
                    ))
                }
            }
            Backend::Auto => {
                let small = match &req.body {
                    RequestBody::Sdp(p) => p.n <= NATIVE_SDP_CUTOFF,
                    RequestBody::Mcm { problem, .. } => problem.n() <= NATIVE_MCM_CUTOFF,
                    RequestBody::Align(p) => {
                        p.rows().max(p.cols()) <= NATIVE_ALIGN_CUTOFF
                    }
                    RequestBody::Viterbi(_) | RequestBody::Cyk(_) => true,
                    RequestBody::Stats => true,
                };
                if !small && fits_xla(req) {
                    Ok(Route::Xla)
                } else {
                    Ok(Route::Native)
                }
            }
        }
    }

    /// Execute one request (already routed).
    pub fn execute(&self, req: &Request, route: Route) -> Response {
        self.execute_with_batch(req, route, 1, &SolveControls::default())
    }

    /// [`Router::execute`] with an absolute deadline: the native executors
    /// poll a [`CancelToken`] derived from it at superstep boundaries and
    /// give up with a typed `timeout` reply once it passes.
    pub fn execute_with_deadline(
        &self,
        req: &Request,
        route: Route,
        deadline: Option<Instant>,
    ) -> Response {
        let controls = SolveControls {
            deadline,
            progress: None,
        };
        self.execute_with_batch(req, route, 1, &controls)
    }

    /// [`Router::execute`] with the same-kind group width threaded
    /// through to the native policy (see [`Router::execute_native`]) and
    /// the caller-computed absolute deadline (if the request carried
    /// `deadline_ms`).  Executor errors map to typed replies here:
    /// `Timeout` → `timeout`, `TooLarge` → `too_large`, `Internal` →
    /// `internal` (a certifier refusal, DESIGN.md §10), the rest keep
    /// the untyped error string.
    fn execute_with_batch(
        &self,
        req: &Request,
        route: Route,
        batch: usize,
        controls: &SolveControls,
    ) -> Response {
        let result = match route {
            Route::Native => self.execute_native(req, batch, controls),
            Route::Xla => self.execute_xla(req),
        };
        match result {
            Ok(r) => r,
            Err(Error::Timeout(_)) => Response::timeout(req.id),
            Err(Error::TooLarge(m)) => Response::too_large(req.id, m),
            Err(Error::Internal(m)) => Response::internal(req.id, m),
            Err(e) => Response::err(req.id, e.to_string()),
        }
    }

    /// Native execution through the adaptive executor policy
    /// (DESIGN.md §7): every request takes the empirically fastest of
    /// seq / fused / pooled / simd for its kind and size, and the chosen
    /// executor is recorded in `served_by` (e.g.
    /// `native:mcm_pipeline_corrected[pooled]`) so clients and tests can
    /// observe the decision.  `batch` is the same-kind group width the
    /// request arrived in — wide groups bias the policy away from the
    /// shared pool (it would serialize them).
    fn execute_native(
        &self,
        req: &Request,
        batch: usize,
        controls: &SolveControls,
    ) -> Result<Response> {
        let table = policy::current();
        let mut token = match controls.deadline {
            Some(d) => CancelToken::at(d),
            None => CancelToken::never(),
        };
        // a streamed request observes the solve through the token's poll
        // sites; is_never() then reports false, steering every kind below
        // onto its `*_cancellable` twin (the only executors that poll)
        let streaming = controls.progress.is_some();
        if let Some(p) = &controls.progress {
            token = token.with_progress(p.clone());
        }
        token.check()?;
        match &req.body {
            RequestBody::Sdp(p) => {
                faults::inject("sdp");
                // keyed by k: the S-DP pipeline's parallelism is its lane
                // count, not the table length — a long, narrow pipe has
                // nothing for the pooled executor to spread
                let choice = pollable_choice(table.choose(Workload::Sdp, p.k(), batch), streaming);
                // no uncertified schedule executes, whatever the choice:
                // seq walks the same dependence structure the pipeline does
                certify::gate_sdp(p.n, &p.offsets)?;
                let st = if token.is_never() {
                    match choice {
                        ExecutorChoice::Seq => crate::sdp::seq::solve(p),
                        // S-DP has no simd kernel (the pipe is a serial
                        // scan, not a reduction) — simd serves as fused
                        ExecutorChoice::Fused | ExecutorChoice::Simd => {
                            crate::sdp::pipeline::solve(p)
                        }
                        ExecutorChoice::Pooled => crate::sdp::pipeline::solve_pooled(p),
                    }
                } else {
                    // seq has no superstep boundaries to poll; the entry
                    // check above is its only cancellation point
                    match choice {
                        ExecutorChoice::Seq => crate::sdp::seq::solve(p),
                        ExecutorChoice::Fused | ExecutorChoice::Simd => {
                            crate::sdp::pipeline::solve_cancellable(p, &token)?
                        }
                        ExecutorChoice::Pooled => {
                            crate::sdp::pipeline::solve_pooled_cancellable(p, &token)?
                        }
                    }
                };
                Ok(self.done(
                    req,
                    st,
                    &format!("native:sdp_pipeline[{}]", choice.name()),
                ))
            }
            RequestBody::Mcm { problem, variant } => match variant {
                McmVariant::Corrected => {
                    faults::inject("mcm");
                    let choice =
                        pollable_choice(table.choose(Workload::Mcm, problem.n(), batch), streaming);
                    // certify the schedule this choice will actually run:
                    // the pooled executor sweeps the cache-blocked
                    // regrouping of the superstep-tiled arena (ISSUE 9),
                    // the simd route runs the schedule-free dual-table
                    // sweep (nothing to certify beyond the untiled
                    // order, which its diagonal loop realizes), and
                    // everything else the untiled arena (tile = 1)
                    let n = problem.n().max(1);
                    if choice == ExecutorChoice::Pooled {
                        certify::gate_mcm_blocked(
                            n,
                            default_mcm_tile(n),
                            crate::core::schedule::default_mcm_block(),
                        )?;
                    } else {
                        certify::gate_mcm(n, McmVariant::Corrected, 1)?;
                    }
                    let served = format!("native:mcm_pipeline_corrected[{}]", choice.name());
                    if req.want_solution && !streaming {
                        // the recording executors fill the split sidecar
                        // alongside the table; seq derives it from the
                        // classic DP loop (one tie-break everywhere)
                        let (st, splits) = match choice {
                            ExecutorChoice::Seq => {
                                crate::mcm::seq::linear_table_with_splits(problem)
                            }
                            ExecutorChoice::Fused => {
                                crate::mcm::pipeline::solve_recorded(problem)
                            }
                            ExecutorChoice::Pooled => {
                                crate::mcm::pipeline::solve_pooled_recorded(problem)
                            }
                            ExecutorChoice::Simd => {
                                crate::mcm::pipeline::solve_simd_recorded(problem)
                            }
                        };
                        let parens =
                            traceback::parenthesization(problem.n().max(1), &splits);
                        let mut resp = self.done(req, st, &served);
                        resp.solution = Some(mcm_solution_json(&parens));
                        return Ok(resp);
                    }
                    let st = if token.is_never() {
                        match choice {
                            ExecutorChoice::Seq => crate::mcm::seq::linear_table(problem),
                            ExecutorChoice::Fused => {
                                crate::mcm::pipeline::solve(problem, McmVariant::Corrected)
                            }
                            ExecutorChoice::Pooled => {
                                crate::mcm::pipeline::solve_pooled(problem)
                            }
                            ExecutorChoice::Simd => crate::mcm::pipeline::solve_simd(problem),
                        }
                    } else {
                        match choice {
                            ExecutorChoice::Seq => crate::mcm::seq::linear_table(problem),
                            ExecutorChoice::Fused => crate::mcm::pipeline::solve_cancellable(
                                problem,
                                McmVariant::Corrected,
                                &token,
                            )?,
                            ExecutorChoice::Pooled => {
                                crate::mcm::pipeline::solve_pooled_cancellable(problem, &token)?
                            }
                            ExecutorChoice::Simd => {
                                crate::mcm::pipeline::solve_simd_cancellable(problem, &token)?
                            }
                        }
                    };
                    if req.want_solution {
                        // streamed solves run the pollable (non-recording)
                        // executor and reconstruct the parenthesization
                        // from the finished table — bit-identical to the
                        // sidecar route by determinism (the XLA path
                        // already relies on this, see execute_xla)
                        let parens =
                            traceback::mcm_parenthesization_from_table(problem, &st);
                        let mut resp = self.done(req, st, &served);
                        resp.solution = Some(mcm_solution_json(&parens));
                        return Ok(resp);
                    }
                    Ok(self.done(req, st, &served))
                }
                // the faithful variant reproduces the published schedule's
                // stale-read semantics — only the two-phase pipeline
                // executor realizes those, so the policy does not apply
                // (and no meaningful solution can be reconstructed)
                McmVariant::PaperFaithful => {
                    faults::inject("mcm");
                    // the faithful bar is WAW-cleanliness only — its stale
                    // reads are the documented semantics, not a hazard
                    certify::gate_mcm(problem.n().max(1), McmVariant::PaperFaithful, 1)?;
                    if req.want_solution {
                        return Err(faithful_solution_error());
                    }
                    let st = if token.is_never() {
                        crate::mcm::pipeline::solve(problem, McmVariant::PaperFaithful)
                    } else {
                        crate::mcm::pipeline::solve_cancellable(
                            problem,
                            McmVariant::PaperFaithful,
                            &token,
                        )?
                    };
                    Ok(self.done(req, st, "native:mcm_pipeline_faithful"))
                }
            },
            RequestBody::Align(p) => {
                faults::inject("align");
                // keyed by the SHORT side: the wavefront's parallelism is
                // min(m, n), so a skinny grid has nothing for the pooled
                // block executor to spread and belongs to seq/fused even
                // when its long side is huge
                let choice = pollable_choice(
                    table.choose(Workload::Align, p.rows().min(p.cols()), batch),
                    streaming,
                );
                // mirror the pooled executor's short-side fallback: it
                // only compiles the tiled schedule when both sides exceed
                // the default tile, otherwise it runs the untiled arena
                let (rows, cols) = (p.rows(), p.cols());
                let pool_tile = default_align_tile(rows, cols);
                let tile = if choice == ExecutorChoice::Pooled && rows.min(cols) > pool_tile
                {
                    pool_tile
                } else {
                    1
                };
                certify::gate_align(rows, cols, tile)?;
                let served = format!("native:align_wavefront[{}]", choice.name());
                if req.want_solution && !streaming {
                    let (st, moves) = match choice {
                        ExecutorChoice::Seq => crate::align::seq::solve_with_moves(p),
                        ExecutorChoice::Fused => crate::align::wavefront::solve_recorded(p),
                        ExecutorChoice::Pooled => {
                            crate::align::wavefront::solve_pooled_recorded(p)
                        }
                        ExecutorChoice::Simd => {
                            crate::align::wavefront::solve_simd_recorded(p)
                        }
                    };
                    let sol = traceback::align_solution(p, &st, &moves);
                    let value = p.scalar(&st);
                    let mut resp = self.done_scored(req, value, st, &served);
                    resp.solution = Some(sol.to_json());
                    return Ok(resp);
                }
                let st = if token.is_never() {
                    match choice {
                        ExecutorChoice::Seq => crate::align::seq::solve(p),
                        ExecutorChoice::Fused => crate::align::wavefront::solve(p),
                        ExecutorChoice::Pooled => crate::align::wavefront::solve_pooled(p),
                        ExecutorChoice::Simd => crate::align::wavefront::solve_simd(p),
                    }
                } else {
                    match choice {
                        ExecutorChoice::Seq => crate::align::seq::solve(p),
                        ExecutorChoice::Fused => {
                            crate::align::wavefront::solve_cancellable(p, &token)?
                        }
                        ExecutorChoice::Pooled => {
                            crate::align::wavefront::solve_pooled_cancellable(p, &token)?
                        }
                        ExecutorChoice::Simd => {
                            crate::align::wavefront::solve_simd_cancellable(p, &token)?
                        }
                    }
                };
                let value = p.scalar(&st); // local alignment's scalar is the max, not the corner
                if req.want_solution {
                    // streamed: pollable executor + from-table traceback,
                    // same reconstruction the XLA path uses
                    let sol = traceback::align_solution_from_table(p, &st).to_json();
                    let mut resp = self.done_scored(req, value, st, &served);
                    resp.solution = Some(sol);
                    return Ok(resp);
                }
                Ok(self.done_scored(req, value, st, &served))
            }
            RequestBody::Viterbi(p) => {
                faults::inject("viterbi");
                // keyed by state count: a lattice column holds S cells,
                // and that is all a superstep has to spread
                let mut choice =
                    pollable_choice(table.choose(Workload::Viterbi, p.num_states, batch), streaming);
                if streaming && choice == ExecutorChoice::Simd {
                    // the simd column sweep polls only at entry — no
                    // sample points for a streamed solve
                    choice = ExecutorChoice::Fused;
                }
                certify::gate_viterbi(p.num_steps(), p.num_states)?;
                let served = format!("native:viterbi_lattice[{}]", choice.name());
                if req.want_solution {
                    let (st, bp) = match choice {
                        ExecutorChoice::Seq => crate::viterbi::seq::solve_with_backpointers(p),
                        ExecutorChoice::Fused => crate::viterbi::pipeline::execute_recorded(p),
                        ExecutorChoice::Pooled => {
                            let pool = crate::runtime::exec_pool::global();
                            crate::viterbi::pipeline::execute_pooled_recorded(
                                p,
                                pool,
                                pool.threads(),
                            )
                        }
                        ExecutorChoice::Simd => {
                            crate::viterbi::pipeline::execute_simd_recorded(p)
                        }
                    };
                    let sol = traceback::viterbi_path(p.num_states, &st, &bp);
                    let mut resp = self.done_log(req, sol.score, st, &served);
                    resp.solution = Some(sol.to_json());
                    return Ok(resp);
                }
                let st = if token.is_never() {
                    match choice {
                        ExecutorChoice::Seq => crate::viterbi::seq::solve(p),
                        ExecutorChoice::Fused => crate::viterbi::pipeline::execute(p),
                        ExecutorChoice::Pooled => crate::viterbi::pipeline::solve_pooled(p),
                        ExecutorChoice::Simd => crate::viterbi::pipeline::execute_simd(p),
                    }
                } else {
                    match choice {
                        // like seq, the simd column sweep polls only at
                        // entry (`token.check()` above) — one lattice is
                        // a short scan
                        ExecutorChoice::Seq => crate::viterbi::seq::solve(p),
                        ExecutorChoice::Simd => crate::viterbi::pipeline::execute_simd(p),
                        ExecutorChoice::Fused => {
                            crate::viterbi::pipeline::execute_cancellable(p, &token)?
                        }
                        ExecutorChoice::Pooled => {
                            crate::viterbi::pipeline::solve_pooled_cancellable(p, &token)?
                        }
                    }
                };
                let score = viterbi_score(p.num_states, &st);
                Ok(self.done_log(req, score, st, &served))
            }
            RequestBody::Cyk(p) => {
                faults::inject("cyk");
                let n = p.n();
                let choice = pollable_choice(table.choose(Workload::Cyk, n, batch), streaming);
                // certify the MCM schedule this choice will actually
                // retag and run: tiled for pooled, untiled otherwise
                let tile = if choice == ExecutorChoice::Pooled {
                    default_mcm_tile(n)
                } else {
                    1
                };
                certify::gate_cyk(n, tile)?;
                let served = format!("native:cyk_mcm_schedule[{}]", choice.name());
                if req.want_solution {
                    let (st, splits) = match choice {
                        ExecutorChoice::Seq => crate::cyk::seq::solve_with_splits(p),
                        ExecutorChoice::Fused => crate::cyk::pipeline::solve_recorded(p),
                        ExecutorChoice::Pooled => {
                            let sched = crate::core::cache::cyk_schedule(n, tile);
                            let pool = crate::runtime::exec_pool::global();
                            crate::cyk::pipeline::execute_pooled_recorded(
                                p,
                                &sched,
                                pool,
                                pool.threads(),
                            )
                        }
                        ExecutorChoice::Simd => crate::cyk::pipeline::solve_simd_recorded(p),
                    };
                    let sol = traceback::cyk_parse(p, &st, &splits);
                    let mut resp = self.done_log(req, sol.score, st, &served);
                    resp.solution = Some(sol.to_json());
                    return Ok(resp);
                }
                let st = if token.is_never() {
                    match choice {
                        ExecutorChoice::Seq => crate::cyk::seq::solve(p),
                        ExecutorChoice::Fused => crate::cyk::pipeline::solve(p),
                        ExecutorChoice::Pooled => crate::cyk::pipeline::solve_pooled(p),
                        ExecutorChoice::Simd => crate::cyk::pipeline::solve_simd(p),
                    }
                } else {
                    match choice {
                        ExecutorChoice::Seq => crate::cyk::seq::solve(p),
                        ExecutorChoice::Fused => {
                            let sched = crate::core::cache::cyk_schedule(n, 1);
                            crate::cyk::pipeline::execute_cancellable(p, &sched, &token)?
                        }
                        ExecutorChoice::Pooled => {
                            crate::cyk::pipeline::solve_pooled_cancellable(p, &token)?
                        }
                        ExecutorChoice::Simd => {
                            crate::cyk::pipeline::solve_simd_cancellable(p, &token)?
                        }
                    }
                };
                let score = cyk_score(p, &st);
                Ok(self.done_log(req, score, st, &served))
            }
            RequestBody::Stats => Err(Error::Server("stats handled by server".into())),
        }
    }

    fn execute_xla(&self, req: &Request) -> Result<Response> {
        let engine = self
            .engine
            .as_ref()
            .ok_or_else(|| Error::Runtime("engine unavailable".into()))?;
        match &req.body {
            RequestBody::Sdp(p) => {
                let st = engine.solve_sdp(p)?;
                Ok(self.done(req, st, "xla:sdp_pipeline"))
            }
            RequestBody::Mcm { problem, variant } => {
                let st = match variant {
                    McmVariant::Corrected => engine.solve_mcm(problem)?,
                    McmVariant::PaperFaithful => {
                        if req.want_solution {
                            return Err(faithful_solution_error());
                        }
                        engine.solve_mcm_pipeline(problem, McmVariant::PaperFaithful)?
                    }
                };
                // the XLA kernels return tables without argmin sidecars;
                // reconstruction recomputes them from the extracted
                // (unpadded) table — bit-identical by determinism, and
                // pad-invariant because extraction is (engine tests)
                let solution = (req.want_solution && *variant == McmVariant::Corrected)
                    .then(|| {
                        mcm_solution_json(&traceback::mcm_parenthesization_from_table(
                            problem, &st,
                        ))
                    });
                let mut resp = self.done(req, st, "xla:mcm");
                resp.solution = solution;
                Ok(resp)
            }
            RequestBody::Align(p) => {
                let st = engine.solve_align(p)?;
                let value = p.scalar(&st);
                let solution = req
                    .want_solution
                    .then(|| traceback::align_solution_from_table(p, &st).to_json());
                let mut resp = self.done_scored(req, value, st, "xla:align_wavefront");
                resp.solution = solution;
                Ok(resp)
            }
            // route() never sends these here (fits_xla is false); a
            // direct call still gets a typed answer, not a panic
            RequestBody::Viterbi(_) | RequestBody::Cyk(_) => Err(Error::Runtime(
                "the log-space families are served natively only".into(),
            )),
            RequestBody::Stats => Err(Error::Server("stats handled by server".into())),
        }
    }

    /// Execute a group of same-bucket requests, batched when a batch
    /// artifact exists; falls back to per-request execution (native
    /// fallbacks tell the policy the group width so it spreads wide
    /// groups across pool-free executors).
    pub fn execute_group(&self, reqs: &[Request], route: Route) -> Vec<Response> {
        self.execute_group_with_deadlines(reqs, route, &[])
    }

    /// [`Router::execute_group`] with per-request absolute deadlines
    /// (parallel to `reqs`; missing/short slices mean "no deadline").
    /// XLA dispatches are not cancellable mid-flight — the batcher sheds
    /// already-expired entries before calling here, so a deadline only
    /// cuts native solves at superstep boundaries.
    pub fn execute_group_with_deadlines(
        &self,
        reqs: &[Request],
        route: Route,
        deadlines: &[Option<Instant>],
    ) -> Vec<Response> {
        let controls: Vec<SolveControls> = reqs
            .iter()
            .enumerate()
            .map(|(i, _)| SolveControls {
                deadline: deadlines.get(i).copied().flatten(),
                progress: None,
            })
            .collect();
        self.execute_group_with_controls(reqs, route, &controls)
    }

    /// [`Router::execute_group_with_deadlines`] with full per-request
    /// [`SolveControls`] (parallel to `reqs`; missing entries mean "no
    /// controls").  Progress observers apply to native solves only: an
    /// XLA dispatch is a single opaque call with nothing to sample, so a
    /// streamed request served by XLA yields its terminal frame without
    /// intermediate progress.
    pub fn execute_group_with_controls(
        &self,
        reqs: &[Request],
        route: Route,
        controls: &[SolveControls],
    ) -> Vec<Response> {
        if route == Route::Xla && reqs.len() > 1 {
            if let Some(responses) = self.try_execute_batched(reqs) {
                return responses;
            }
        }
        let batch = reqs.len();
        let default = SolveControls::default();
        reqs.iter()
            .enumerate()
            .map(|(i, r)| {
                let c = controls.get(i).unwrap_or(&default);
                self.execute_with_batch(r, route, batch, c)
            })
            .collect()
    }

    fn try_execute_batched(&self, reqs: &[Request]) -> Option<Vec<Response>> {
        let engine = self.engine.as_ref()?;
        // homogeneous-kind groups only (the batcher's key guarantees this)
        match &reqs[0].body {
            RequestBody::Sdp(_) => {
                let ps: Vec<&SdpProblem> = reqs
                    .iter()
                    .map(|r| match &r.body {
                        RequestBody::Sdp(p) => p,
                        _ => unreachable!("batch key mixes kinds"),
                    })
                    .collect();
                let first = ps[0];
                engine.registry.route_sdp(first.n, first.k(), first.op, ps.len())?;
                let tables = engine.solve_sdp_batch(&ps).ok()?;
                Some(
                    reqs.iter()
                        .zip(tables)
                        .map(|(r, st)| self.done(r, st, "xla:sdp_pipeline[batched]"))
                        .collect(),
                )
            }
            RequestBody::Mcm { .. } => {
                let ps: Vec<&McmProblem> = reqs
                    .iter()
                    .map(|r| match &r.body {
                        RequestBody::Mcm { problem, .. } => problem,
                        _ => unreachable!("batch key mixes kinds"),
                    })
                    .collect();
                let n_max = ps.iter().map(|p| p.n()).max()?;
                engine.registry.route_mcm(n_max, "diagonal", ps.len())?;
                let tables = engine.solve_mcm_batch(&ps).ok()?;
                Some(
                    reqs.iter()
                        .zip(ps.iter().zip(tables))
                        .map(|(r, (p, st))| {
                            // group keys are variant-homogeneous; faithful
                            // groups cannot reconstruct (see execute_xla)
                            let solution = match (&r.body, r.want_solution) {
                                (
                                    RequestBody::Mcm {
                                        variant: McmVariant::Corrected,
                                        ..
                                    },
                                    true,
                                ) => Some(mcm_solution_json(
                                    &traceback::mcm_parenthesization_from_table(p, &st),
                                )),
                                (_, true) => {
                                    return Response::err(
                                        r.id,
                                        faithful_solution_error().to_string(),
                                    )
                                }
                                _ => None,
                            };
                            let mut resp = self.done(r, st, "xla:mcm_diagonal[batched]");
                            resp.solution = solution;
                            resp
                        })
                        .collect(),
                )
            }
            RequestBody::Align(_) => {
                let ps: Vec<&AlignProblem> = reqs
                    .iter()
                    .map(|r| match &r.body {
                        RequestBody::Align(p) => p,
                        _ => unreachable!("batch key mixes kinds"),
                    })
                    .collect();
                let rows = ps.iter().map(|p| p.rows()).max()?;
                let cols = ps.iter().map(|p| p.cols()).max()?;
                engine.registry.route_align(rows, cols, ps.len())?;
                let tables = engine.solve_align_batch(&ps).ok()?;
                Some(
                    reqs.iter()
                        .zip(ps.iter().zip(tables))
                        .map(|(r, (p, st))| {
                            let value = p.scalar(&st);
                            let solution = r
                                .want_solution
                                .then(|| traceback::align_solution_from_table(p, &st).to_json());
                            let mut resp =
                                self.done_scored(r, value, st, "xla:align_wavefront[batched]");
                            resp.solution = solution;
                            resp
                        })
                        .collect(),
                )
            }
            RequestBody::Viterbi(_) | RequestBody::Cyk(_) | RequestBody::Stats => None,
        }
    }

    fn done(&self, req: &Request, table: Vec<i64>, served_by: &str) -> Response {
        let value = *table.last().unwrap_or(&0);
        self.done_scored(req, value, table, served_by)
    }

    /// [`Router::done_scored`] for the log-space families: the scalar
    /// answer is a log-probability (`score` on the wire, `value` = 0)
    /// and the optional full table rides `ftable` (docs/PROTOCOL.md).
    fn done_log(&self, req: &Request, score: f64, table: Vec<f64>, served_by: &str) -> Response {
        Response::ok_score(
            req.id,
            score,
            served_by.to_string(),
            if req.full { Some(table) } else { None },
        )
    }

    /// Like [`Router::done`] for workloads whose scalar answer is not the
    /// table's last cell (local alignment reports the table maximum).
    fn done_scored(
        &self,
        req: &Request,
        value: i64,
        table: Vec<i64>,
        served_by: &str,
    ) -> Response {
        Response::ok(
            req.id,
            value,
            served_by.to_string(),
            if req.full { Some(table) } else { None },
        )
    }
}

/// Batching key: requests with equal keys can share one dispatch.
///
/// `Ord` exists for the batcher's deadline heap (`Reverse<(Instant,
/// GroupKey)>` entries need a total order); the ordering itself carries
/// no meaning.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GroupKey {
    Sdp {
        n: usize,
        k: usize,
        op: &'static str,
    },
    Mcm {
        n: usize,
        variant: &'static str,
    },
    /// Variant and scoring are deliberately absent: the batched dispatch
    /// carries them per instance in the params literal, so same-shape
    /// requests of different variants share one dispatch.
    Align {
        rows: usize,
        cols: usize,
    },
    Single(i64),
}

/// Compute the batching key for a routed request; `Single` keys are never
/// merged (stats, native routes get trivially unique keys).
pub fn group_key(req: &Request, route: Route) -> GroupKey {
    if route != Route::Xla {
        return GroupKey::Single(req.id);
    }
    match &req.body {
        RequestBody::Sdp(p) => GroupKey::Sdp {
            n: p.n,
            k: p.k(),
            op: p.op.name(),
        },
        RequestBody::Mcm { problem, variant } => GroupKey::Mcm {
            n: problem.n(),
            variant: variant.name(),
        },
        RequestBody::Align(p) => GroupKey::Align {
            rows: p.rows(),
            cols: p.cols(),
        },
        // native-only kinds never reach an XLA group, but a key must
        // exist: trivially unique, so they never merge
        RequestBody::Viterbi(_) | RequestBody::Cyk(_) => GroupKey::Single(req.id),
        RequestBody::Stats => GroupKey::Single(req.id),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::semigroup::Op;

    fn sdp_req(id: i64, n: usize, backend: Backend) -> Request {
        Request {
            id,
            body: RequestBody::Sdp(
                SdpProblem::new(n, vec![2, 1], Op::Min, vec![5, 3]).unwrap(),
            ),
            backend,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        }
    }

    #[test]
    fn engineless_router_always_native() {
        let r = Router::new(None);
        assert_eq!(r.route(&sdp_req(1, 1000, Backend::Auto)).unwrap(), Route::Native);
        assert!(r.route(&sdp_req(1, 1000, Backend::Xla)).is_err());
    }

    #[test]
    fn native_execution_solves() {
        let r = Router::new(None);
        let mut req = sdp_req(1, 16, Backend::Native);
        req.body = RequestBody::Sdp(SdpProblem::fibonacci(16));
        req.full = true;
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok);
        assert_eq!(resp.value, 987);
        assert_eq!(resp.table.unwrap().len(), 16);
    }

    #[test]
    fn mcm_native_execution() {
        let r = Router::new(None);
        let req = Request {
            id: 2,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::Corrected,
            },
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok);
        assert_eq!(resp.value, 15125);
    }

    #[test]
    fn faithful_variant_served_and_marked() {
        let r = Router::new(None);
        let req = Request {
            id: 3,
            body: RequestBody::Mcm {
                problem: McmProblem::hazard_counterexample(),
                variant: McmVariant::PaperFaithful,
            },
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok);
        assert!(resp.served_by.contains("faithful"));
        // the published schedule overestimates this instance
        let truth = crate::mcm::seq::cost(&McmProblem::hazard_counterexample());
        assert!(resp.value > truth);
    }

    #[test]
    fn align_native_execution_scores_by_variant() {
        use crate::core::problem::{AlignProblem, AlignScoring, AlignVariant};
        let r = Router::new(None);
        // LCS: corner cell
        let req = Request {
            id: 4,
            body: RequestBody::Align(
                AlignProblem::lcs(vec![1, 2, 3, 4, 7], vec![2, 3, 9, 4]).unwrap(),
            ),
            backend: Backend::Native,
            full: true,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.value, 3);
        assert!(
            resp.served_by.starts_with("native:align_wavefront["),
            "policy choice must be visible: {}",
            resp.served_by
        );
        assert_eq!(resp.table.unwrap().len(), 6 * 5);
        // local alignment: the value is the table max, not the corner
        let p = AlignProblem::new(
            vec![1, 2, 3, 9],
            vec![8, 1, 2, 3],
            AlignVariant::Local,
            AlignScoring::default(),
        )
        .unwrap();
        let want = crate::align::seq::score(&p);
        let req = Request {
            id: 5,
            body: RequestBody::Align(p),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok);
        assert_eq!(resp.value, want);
        assert_eq!(want, 6); // run {1,2,3} × match_s 2
    }

    #[test]
    fn native_served_by_reports_policy_choice() {
        // whatever the installed policy picks, the suffix must name one
        // of the native executors and the answer must match the oracle
        let r = Router::new(None);
        let p = McmProblem::clrs();
        let want = crate::mcm::seq::cost(&p);
        let req = Request {
            id: 7,
            body: RequestBody::Mcm {
                problem: p,
                variant: McmVariant::Corrected,
            },
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok);
        assert_eq!(resp.value, want);
        let suffix_ok = ["[seq]", "[fused]", "[pooled]", "[simd]"]
            .iter()
            .any(|s| resp.served_by.ends_with(s));
        assert!(
            resp.served_by.starts_with("native:mcm_pipeline_corrected[") && suffix_ok,
            "{}",
            resp.served_by
        );
    }

    #[test]
    fn every_policy_choice_solves_correctly_via_router() {
        // pin each choice through an explicit table: every executor
        // answers identically through the native path
        use crate::core::policy::{ExecutorChoice, PolicyTable, Workload};
        let _guard = crate::core::policy::test_install_lock()
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let r = Router::new(None);
        let p = McmProblem::clrs();
        let want = crate::mcm::seq::cost(&p);
        for choice in ExecutorChoice::ALL {
            let mut t = PolicyTable::uncalibrated(4);
            // a single row whose winner is the pinned choice at any size
            let costs = ExecutorChoice::ALL
                .iter()
                .map(|&c| (c, if c == choice { 1.0 } else { 2.0 }))
                .collect();
            t.push_measurement(Workload::Mcm, 6, costs);
            crate::core::policy::install(t);
            let req = Request {
                id: 8,
                body: RequestBody::Mcm {
                    problem: p.clone(),
                    variant: McmVariant::Corrected,
                },
                backend: Backend::Native,
                full: false,
                want_solution: false,
                deadline_ms: None,
                stream: false,
            };
            let resp = r.execute(&req, Route::Native);
            assert!(resp.ok, "{choice:?}");
            assert_eq!(resp.value, want, "{choice:?}");
            // a pinned Pooled choice may legitimately report [fused] if a
            // concurrent test keeps the shared pool busy at this instant
            // (the deterministic downgrade logic is unit-tested in
            // core::policy); seq/fused are never rerouted
            let served_ok = resp.served_by.ends_with(&format!("[{}]", choice.name()))
                || (choice == ExecutorChoice::Pooled
                    && resp.served_by.ends_with("[fused]"));
            assert!(served_ok, "{choice:?}: {}", resp.served_by);
        }
        // leave a clean slate for other tests in this process
        crate::core::policy::install(PolicyTable::uncalibrated(4));
    }

    #[test]
    fn want_solution_native_mcm_and_align() {
        use crate::core::problem::AlignProblem;
        let r = Router::new(None);
        // mcm corrected: the CLRS parenthesization rides the reply
        let req = Request {
            id: 9,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::Corrected,
            },
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.value, 15125);
        let sol = resp.solution.expect("mcm solution present");
        assert_eq!(sol.str_field("parens").unwrap(), "((A1(A2A3))((A4A5)A6))");

        // faithful + want_solution: typed refusal, not a wrong answer
        let req = Request {
            id: 10,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::PaperFaithful,
            },
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(!resp.ok);
        assert!(
            resp.error.as_deref().unwrap_or("").contains("corrected"),
            "{:?}",
            resp.error
        );

        // align: script present, replayed score equals the wire value
        let p = AlignProblem::lcs(vec![1, 2, 3, 4, 7], vec![2, 3, 9, 4]).unwrap();
        let req = Request {
            id: 11,
            body: RequestBody::Align(p),
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok, "{:?}", resp.error);
        let sol = resp.solution.expect("align solution present");
        assert_eq!(sol.i64_field("score").unwrap(), resp.value);
        assert!(!sol.str_field("ops").unwrap().is_empty());
        // solutions are opt-in: a plain request carries none
        let plain = Request {
            id: 12,
            body: RequestBody::Align(
                AlignProblem::lcs(vec![1, 2], vec![2, 1]).unwrap(),
            ),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&plain, Route::Native);
        assert!(resp.ok);
        assert!(resp.solution.is_none());
    }

    #[test]
    fn every_policy_choice_reconstructs_identical_solutions() {
        // pin each executor choice: all three traceback routes must
        // produce the same parenthesization and the same edit script
        use crate::core::policy::{ExecutorChoice, PolicyTable, Workload};
        use crate::core::problem::{AlignProblem, AlignScoring, AlignVariant};
        let _guard = crate::core::policy::test_install_lock()
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let r = Router::new(None);
        let mcm = McmProblem::clrs();
        let align = AlignProblem::new(
            vec![10, 8, 19, 19, 4, 13],
            vec![18, 8, 19, 19, 8, 13, 6],
            AlignVariant::Edit,
            AlignScoring::default(),
        )
        .unwrap();
        let mut parens_seen = std::collections::HashSet::new();
        let mut ops_seen = std::collections::HashSet::new();
        for choice in ExecutorChoice::ALL {
            let mut t = PolicyTable::uncalibrated(4);
            for wl in [Workload::Mcm, Workload::Align] {
                let costs = ExecutorChoice::ALL
                    .iter()
                    .map(|&c| (c, if c == choice { 1.0 } else { 2.0 }))
                    .collect();
                t.push_measurement(wl, 6, costs);
            }
            crate::core::policy::install(t);
            let resp = r.execute(
                &Request {
                    id: 1,
                    body: RequestBody::Mcm {
                        problem: mcm.clone(),
                        variant: McmVariant::Corrected,
                    },
                    backend: Backend::Native,
                    full: false,
                    want_solution: true,
                    deadline_ms: None,
                    stream: false,
                },
                Route::Native,
            );
            assert!(resp.ok, "{choice:?}");
            parens_seen.insert(
                resp.solution
                    .unwrap()
                    .str_field("parens")
                    .unwrap()
                    .to_string(),
            );
            let resp = r.execute(
                &Request {
                    id: 2,
                    body: RequestBody::Align(align.clone()),
                    backend: Backend::Native,
                    full: false,
                    want_solution: true,
                    deadline_ms: None,
                    stream: false,
                },
                Route::Native,
            );
            assert!(resp.ok, "{choice:?}");
            assert_eq!(resp.value, 3, "{choice:?}"); // kitten → sitting
            ops_seen.insert(resp.solution.unwrap().str_field("ops").unwrap().to_string());
        }
        assert_eq!(parens_seen.len(), 1, "choices disagree: {parens_seen:?}");
        assert_eq!(ops_seen.len(), 1, "choices disagree: {ops_seen:?}");
        crate::core::policy::install(PolicyTable::uncalibrated(4));
    }

    fn small_hmm() -> crate::core::problem::ViterbiProblem {
        let half = (0.5f64).ln();
        crate::core::problem::ViterbiProblem::new(
            2,
            2,
            vec![half, half],
            vec![
                (0.9f64).ln(),
                (0.1f64).ln(),
                (0.1f64).ln(),
                (0.9f64).ln(),
            ],
            vec![
                (0.8f64).ln(),
                (0.2f64).ln(),
                (0.2f64).ln(),
                (0.8f64).ln(),
            ],
            vec![0, 0, 1, 1, 0],
        )
        .unwrap()
    }

    #[test]
    fn viterbi_native_execution_scores_and_decodes() {
        let r = Router::new(None);
        let p = small_hmm();
        let want = crate::viterbi::seq::decode(&p);
        let req = Request {
            id: 20,
            body: RequestBody::Viterbi(p.clone()),
            backend: Backend::Native,
            full: true,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok, "{:?}", resp.error);
        // log-space families answer on `score`, not `value`
        assert_eq!(resp.value, 0);
        assert!((resp.score.unwrap() - want.score).abs() < 1e-12);
        assert_eq!(resp.ftable.as_ref().unwrap().len(), p.num_cells());
        assert!(
            resp.served_by.starts_with("native:viterbi_lattice["),
            "{}",
            resp.served_by
        );
        // want_solution: the state path rides the reply
        let req = Request {
            id: 21,
            body: RequestBody::Viterbi(p.clone()),
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok, "{:?}", resp.error);
        assert!(resp.ftable.is_none());
        let sol = resp.solution.expect("viterbi solution present");
        let states: Vec<i64> = sol
            .arr_field("states")
            .unwrap()
            .iter()
            .map(|s| s.as_i64().unwrap())
            .collect();
        let want_states: Vec<i64> = want.states.iter().map(|&s| s as i64).collect();
        assert_eq!(states, want_states);
        assert!((sol.lognum_field("score").unwrap() - want.score).abs() < 1e-12);
        // auto routes native even engineless; pinned xla is refused
        assert_eq!(r.route(&req).unwrap(), Route::Native);
        let mut pinned = req;
        pinned.backend = Backend::Xla;
        assert!(r.route(&pinned).is_err());
    }

    #[test]
    fn cyk_native_execution_parses_and_reports_unparseable() {
        use crate::core::problem::{CykProblem, CykRule};
        let r = Router::new(None);
        let p = CykProblem::balanced_example(3);
        let req = Request {
            id: 22,
            body: RequestBody::Cyk(p),
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok, "{:?}", resp.error);
        assert!((resp.score.unwrap() - 5.0 * (0.5f64).ln()).abs() < 1e-12);
        assert!(
            resp.served_by.starts_with("native:cyk_mcm_schedule["),
            "{}",
            resp.served_by
        );
        let sol = resp.solution.expect("cyk solution present");
        assert_eq!(
            sol.str_field("tree").unwrap(),
            "(N0 (N0 w0) (N0 (N0 w1) (N0 w2)))"
        );
        // an unparseable sentence is a −∞ answer with a null tree — a
        // modelling outcome, not an error
        let dead = CykProblem::new(
            2,
            1,
            vec![CykRule {
                lhs: 1,
                rhs_b: 1,
                rhs_c: 1,
                logp: (0.5f64).ln(),
            }],
            vec![(1, 0, 0.0)],
            vec![0, 0],
        )
        .unwrap();
        let req = Request {
            id: 23,
            body: RequestBody::Cyk(dead),
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute(&req, Route::Native);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.score, Some(f64::NEG_INFINITY));
        let sol = resp.solution.expect("solution object still present");
        assert!(matches!(sol.get("tree"), Some(Json::Null)));
    }

    #[test]
    fn every_policy_choice_serves_identical_log_space_answers() {
        // pin each executor choice: the three tiers must agree on both
        // the score and the reconstructed solution, bit for bit
        use crate::core::policy::{ExecutorChoice, PolicyTable, Workload};
        let _guard = crate::core::policy::test_install_lock()
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let r = Router::new(None);
        let hmm = small_hmm();
        let cyk = crate::core::problem::CykProblem::balanced_example(5);
        let mut viterbi_seen = std::collections::HashSet::new();
        let mut cyk_seen = std::collections::HashSet::new();
        for choice in ExecutorChoice::ALL {
            let mut t = PolicyTable::uncalibrated(4);
            for wl in [Workload::Viterbi, Workload::Cyk] {
                let costs = ExecutorChoice::ALL
                    .iter()
                    .map(|&c| (c, if c == choice { 1.0 } else { 2.0 }))
                    .collect();
                t.push_measurement(wl, 6, costs);
            }
            crate::core::policy::install(t);
            let resp = r.execute(
                &Request {
                    id: 1,
                    body: RequestBody::Viterbi(hmm.clone()),
                    backend: Backend::Native,
                    full: false,
                    want_solution: true,
                    deadline_ms: None,
                    stream: false,
                },
                Route::Native,
            );
            assert!(resp.ok, "{choice:?}: {:?}", resp.error);
            viterbi_seen.insert(format!(
                "{:?}|{}",
                resp.score.unwrap().to_bits(),
                resp.solution.unwrap().to_string()
            ));
            let resp = r.execute(
                &Request {
                    id: 2,
                    body: RequestBody::Cyk(cyk.clone()),
                    backend: Backend::Native,
                    full: false,
                    want_solution: true,
                    deadline_ms: None,
                    stream: false,
                },
                Route::Native,
            );
            assert!(resp.ok, "{choice:?}: {:?}", resp.error);
            cyk_seen.insert(format!(
                "{:?}|{}",
                resp.score.unwrap().to_bits(),
                resp.solution.unwrap().to_string()
            ));
        }
        assert_eq!(viterbi_seen.len(), 1, "choices disagree: {viterbi_seen:?}");
        assert_eq!(cyk_seen.len(), 1, "choices disagree: {cyk_seen:?}");
        crate::core::policy::install(PolicyTable::uncalibrated(4));
    }

    #[test]
    fn log_space_deadlines_yield_typed_timeouts() {
        use crate::coordinator::request::ErrorKind;
        let r = Router::new(None);
        let req = Request {
            id: 24,
            body: RequestBody::Viterbi(small_hmm()),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute_with_deadline(&req, Route::Native, Some(Instant::now()));
        assert_eq!(resp.error_kind, Some(ErrorKind::Timeout));
        let far = Instant::now() + std::time::Duration::from_secs(600);
        let resp = r.execute_with_deadline(&req, Route::Native, Some(far));
        assert!(resp.ok, "{:?}", resp.error);
        let req = Request {
            id: 25,
            body: RequestBody::Cyk(crate::core::problem::CykProblem::balanced_example(6)),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let resp = r.execute_with_deadline(&req, Route::Native, Some(Instant::now()));
        assert_eq!(resp.error_kind, Some(ErrorKind::Timeout));
        let resp = r.execute_with_deadline(&req, Route::Native, Some(far));
        assert!(resp.ok, "{:?}", resp.error);
    }

    #[test]
    fn align_auto_routes_native_without_engine() {
        let r = Router::new(None);
        let req = Request {
            id: 6,
            body: RequestBody::Align(
                crate::core::problem::AlignProblem::lcs(vec![1; 500], vec![2; 500]).unwrap(),
            ),
            backend: Backend::Auto,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        // large grid, but engineless → native; pinned xla → typed error
        assert_eq!(r.route(&req).unwrap(), Route::Native);
        let mut pinned = req;
        pinned.backend = Backend::Xla;
        assert!(r.route(&pinned).is_err());
    }

    #[test]
    fn align_group_keys_split_by_shape_only() {
        use crate::core::problem::{AlignProblem, AlignScoring, AlignVariant};
        let mk = |id, variant| Request {
            id,
            body: RequestBody::Align(
                AlignProblem::new(vec![1, 2], vec![3, 4, 5], variant, AlignScoring::default())
                    .unwrap(),
            ),
            backend: Backend::Auto,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let a = mk(1, AlignVariant::Lcs);
        let b = mk(2, AlignVariant::Lcs);
        // same shape, different variant: still one dispatch (variant and
        // scoring ride the per-instance params literal)
        let c = mk(3, AlignVariant::Edit);
        assert_eq!(group_key(&a, Route::Xla), group_key(&b, Route::Xla));
        assert_eq!(group_key(&a, Route::Xla), group_key(&c, Route::Xla));
        let mut d = mk(4, AlignVariant::Lcs);
        if let RequestBody::Align(p) = &mut d.body {
            p.b.push(6); // different shape → different bucket
        }
        assert_ne!(group_key(&a, Route::Xla), group_key(&d, Route::Xla));
    }

    #[test]
    fn expired_deadline_yields_typed_timeout() {
        use crate::coordinator::request::ErrorKind;
        let r = Router::new(None);
        // a deadline of "now" is already past by the time the entry gate
        // polls the token — typed timeout, id-correlated, no table
        let req = sdp_req(42, 64, Backend::Native);
        let resp = r.execute_with_deadline(&req, Route::Native, Some(Instant::now()));
        assert!(!resp.ok);
        assert_eq!(resp.id, 42);
        assert_eq!(resp.error_kind, Some(ErrorKind::Timeout));
        assert!(resp.table.is_none());
    }

    #[test]
    fn generous_deadline_solves_identically() {
        let r = Router::new(None);
        let mut req = sdp_req(43, 16, Backend::Native);
        req.body = RequestBody::Sdp(SdpProblem::fibonacci(16));
        let far = Instant::now() + std::time::Duration::from_secs(600);
        let resp = r.execute_with_deadline(&req, Route::Native, Some(far));
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.value, 987);
        assert!(resp.error_kind.is_none());
    }

    #[test]
    fn group_deadlines_apply_per_request() {
        use crate::coordinator::request::ErrorKind;
        let r = Router::new(None);
        let reqs = vec![
            sdp_req(1, 32, Backend::Native),
            sdp_req(2, 32, Backend::Native),
        ];
        let deadlines = vec![
            Some(Instant::now()), // expired
            None,                 // unbounded
        ];
        let resps = r.execute_group_with_deadlines(&reqs, Route::Native, &deadlines);
        assert_eq!(resps.len(), 2);
        assert_eq!(resps[0].error_kind, Some(ErrorKind::Timeout));
        assert!(resps[1].ok, "{:?}", resps[1].error);
    }

    #[test]
    fn streamed_controls_tick_progress_and_reconstruct_solutions() {
        use crate::core::problem::AlignProblem;
        use crate::runtime::exec_pool::Progress;
        use std::sync::atomic::{AtomicU64, Ordering};
        let r = Router::new(None);
        // mcm: the streamed route reconstructs from the finished table and
        // must agree with the recorded-sidecar route, tick for tick
        let frames = Arc::new(AtomicU64::new(0));
        let sink = {
            let f = frames.clone();
            Box::new(move |_s: u64, _c: u64| {
                f.fetch_add(1, Ordering::Relaxed);
            })
        };
        let progress = Arc::new(Progress::new(6, 36, sink));
        let req = Request {
            id: 1,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::Corrected,
            },
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: true,
        };
        let controls = vec![SolveControls {
            deadline: None,
            progress: Some(progress.clone()),
        }];
        let resps = r.execute_group_with_controls(
            std::slice::from_ref(&req),
            Route::Native,
            &controls,
        );
        assert_eq!(resps.len(), 1);
        let resp = &resps[0];
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.value, 15125);
        assert_eq!(
            resp.solution.as_ref().unwrap().str_field("parens").unwrap(),
            "((A1(A2A3))((A4A5)A6))"
        );
        // a streamed solve never lands on the poll-free seq executor
        assert!(!resp.served_by.ends_with("[seq]"), "{}", resp.served_by);
        assert!(progress.supersteps() >= 1, "poll sites must tick");
        assert!(frames.load(Ordering::Relaxed) >= 1);
        // align: streamed from-table traceback replays to the wire value
        let p = AlignProblem::lcs(vec![1, 2, 3, 4, 7], vec![2, 3, 9, 4]).unwrap();
        let progress = Arc::new(Progress::new(8, 30, Box::new(|_, _| {})));
        let req = Request {
            id: 2,
            body: RequestBody::Align(p),
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: true,
        };
        let controls = vec![SolveControls {
            deadline: None,
            progress: Some(progress.clone()),
        }];
        let resps = r.execute_group_with_controls(
            std::slice::from_ref(&req),
            Route::Native,
            &controls,
        );
        let resp = &resps[0];
        assert!(resp.ok, "{:?}", resp.error);
        let sol = resp.solution.as_ref().expect("align solution present");
        assert_eq!(sol.i64_field("score").unwrap(), resp.value);
        assert_eq!(resp.value, 3);
        assert!(progress.supersteps() >= 1);
        // a deadline and an observer compose: expired deadline still wins
        let progress = Arc::new(Progress::new(0, 0, Box::new(|_, _| {})));
        let controls = vec![SolveControls {
            deadline: Some(Instant::now()),
            progress: Some(progress),
        }];
        let resps = r.execute_group_with_controls(
            std::slice::from_ref(&sdp_req(3, 64, Backend::Native)),
            Route::Native,
            &controls,
        );
        assert_eq!(
            resps[0].error_kind,
            Some(crate::coordinator::request::ErrorKind::Timeout)
        );
    }

    #[test]
    fn native_solves_carry_verified_certificates() {
        // every native dispatch passes the certifier gate: the certified
        // counter grows by at least one per solve, across all three kinds
        use crate::core::problem::AlignProblem;
        let r = Router::new(None);
        let before = certify::stats().certified;
        assert!(r.execute(&sdp_req(1, 24, Backend::Native), Route::Native).ok);
        let mcm = Request {
            id: 2,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::Corrected,
            },
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        assert!(r.execute(&mcm, Route::Native).ok);
        let faithful = Request {
            id: 3,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::PaperFaithful,
            },
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        assert!(r.execute(&faithful, Route::Native).ok);
        let align = Request {
            id: 4,
            body: RequestBody::Align(
                AlignProblem::lcs(vec![1, 2, 3], vec![2, 3, 4]).unwrap(),
            ),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        assert!(r.execute(&align, Route::Native).ok);
        let viterbi = Request {
            id: 5,
            body: RequestBody::Viterbi(small_hmm()),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        assert!(r.execute(&viterbi, Route::Native).ok);
        let cyk = Request {
            id: 6,
            body: RequestBody::Cyk(crate::core::problem::CykProblem::balanced_example(4)),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        assert!(r.execute(&cyk, Route::Native).ok);
        assert!(
            certify::stats().certified >= before + 6,
            "each native solve must pass the certifier gate"
        );
    }

    #[test]
    fn group_keys_merge_only_same_bucket() {
        let a = sdp_req(1, 100, Backend::Auto);
        let b = sdp_req(2, 100, Backend::Auto);
        let c = sdp_req(3, 200, Backend::Auto);
        assert_eq!(group_key(&a, Route::Xla), group_key(&b, Route::Xla));
        assert_ne!(group_key(&a, Route::Xla), group_key(&c, Route::Xla));
        // native routes never merge
        assert_ne!(group_key(&a, Route::Native), group_key(&b, Route::Native));
    }
}
