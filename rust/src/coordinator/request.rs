//! Wire protocol: line-delimited JSON requests/responses.
//!
//! The full protocol — every request kind, field, reply shape, the
//! `overloaded` shed semantics and the id-correlation rules pipelined
//! clients rely on — is specified in `docs/PROTOCOL.md`; this module is
//! its reference implementation.

use crate::core::problem::{
    AlignProblem, AlignScoring, AlignVariant, CykProblem, CykRule, McmProblem, SdpProblem,
    ViterbiProblem,
};
use crate::core::schedule::McmVariant;
use crate::core::semigroup::Op;
use crate::util::json::Json;
use crate::{Error, Result};

/// Backend selection on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Router decides (native for tiny instances, XLA when a bucket fits).
    Auto,
    /// Native Rust pipeline executors.
    Native,
    /// AOT-compiled Pallas kernels via PJRT.
    Xla,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "auto" => Ok(Backend::Auto),
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            other => Err(Error::Json(format!("unknown backend '{other}'"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Native => "native",
            Backend::Xla => "xla",
        }
    }
}

/// Typed failure taxonomy on the wire (docs/PROTOCOL.md): every error
/// reply carries at most one kind, and clients branch on it — retry with
/// backoff on `overloaded`, resubmit with a larger budget on `timeout`,
/// shrink or split on `too_large`, report-and-retry-once on `panicked`.
/// Plain validation errors (bad JSON, invalid problems) carry no kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// The request's `deadline_ms` budget expired before (or while)
    /// solving; the solve was shed or cancelled at a superstep boundary.
    Timeout,
    /// The solve panicked; the fault was isolated at the worker-pool
    /// boundary and the server remains healthy.
    Panicked,
    /// The admission gate refused the solve: its estimated table +
    /// sidecar footprint exceeds the server's `max_solve_bytes` budget.
    TooLarge,
    /// The admission gate refused the request because the worker queue
    /// was full (the legacy `overloaded` marker, now typed).
    Overloaded,
    /// An internal server invariant failed — most notably the schedule
    /// certifier refusing to dispatch an uncertified or refuted schedule
    /// (`core::certify`).  Never the client's fault; report it.
    Internal,
}

impl ErrorKind {
    pub fn name(self) -> &'static str {
        match self {
            ErrorKind::Timeout => "timeout",
            ErrorKind::Panicked => "panicked",
            ErrorKind::TooLarge => "too_large",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Result<ErrorKind> {
        match s {
            "timeout" => Ok(ErrorKind::Timeout),
            "panicked" => Ok(ErrorKind::Panicked),
            "too_large" => Ok(ErrorKind::TooLarge),
            "overloaded" => Ok(ErrorKind::Overloaded),
            "internal" => Ok(ErrorKind::Internal),
            other => Err(Error::Json(format!("unknown error_kind '{other}'"))),
        }
    }

    /// Whether a client may retry the identical request and plausibly
    /// succeed (docs/PROTOCOL.md retry guidance): load and transient
    /// faults are retryable; a structurally oversized solve is not, and
    /// neither is a refuted schedule — the same request recompiles the
    /// same schedule and is refused again.
    pub fn retryable(self) -> bool {
        !matches!(self, ErrorKind::TooLarge | ErrorKind::Internal)
    }
}

/// A parsed request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: i64,
    pub body: RequestBody,
    pub backend: Backend,
    /// Return the full solved table (default: scalar summary only).
    pub full: bool,
    /// Reconstruct and return the optimal solution (DESIGN.md §8): the
    /// parenthesization for `mcm` (Corrected only), the edit script +
    /// span for `align`, the state path for `viterbi`, the derivation
    /// tree for `cyk`.  Ignored by `sdp`/`stats`, which have no solution
    /// structure beyond the table itself (docs/PROTOCOL.md).
    pub want_solution: bool,
    /// Per-request latency budget in milliseconds, measured from server
    /// receipt.  Expired requests are shed from the queue (never solved)
    /// and running solves are cancelled at the next superstep boundary;
    /// both reply `error_kind: "timeout"`.  Absent means no deadline.
    pub deadline_ms: Option<u64>,
    /// Opt into streaming partial replies (docs/PROTOCOL.md §Streaming):
    /// the server interleaves incremental `progress` frames (supersteps
    /// completed / cells finalized, sampled at the executor's superstep
    /// boundaries) and, when `want_solution` produces a large traceback,
    /// chunked `solution` frames, before the terminal `result` frame.
    /// Non-streaming requests receive exactly the PR-2 reply shape.
    pub stream: bool,
}

#[derive(Debug, Clone)]
pub enum RequestBody {
    Sdp(SdpProblem),
    Mcm {
        problem: McmProblem,
        variant: McmVariant,
    },
    /// Sequence alignment (LCS / edit distance / local alignment) over
    /// the anti-diagonal wavefront schedule.
    Align(AlignProblem),
    /// HMM maximum-likelihood decoding over the `(max, ×)` log-space
    /// semiring (DESIGN.md §11).  Log-probabilities travel as lognums
    /// (`"-inf"` sentinel — [`Json::lognum`]).
    Viterbi(ViterbiProblem),
    /// Probabilistic CYK parsing over a CNF grammar, reusing the cached
    /// corrected MCM triangular schedule (DESIGN.md §11).
    Cyk(CykProblem),
    /// Server status probe.
    Stats,
}

impl RequestBody {
    /// Estimated peak allocation of solving this body, in bytes: the DP
    /// table plus (when `want_solution` records a sidecar) the traceback
    /// arena.  A cheap upper-bound estimate computed *before* any
    /// allocation — the admission gate compares it against the server's
    /// `max_solve_bytes` budget so a megabase-scale table is refused with
    /// `too_large` instead of OOM-killing the process.
    pub fn estimated_solve_bytes(&self, want_solution: bool) -> u64 {
        const CELL: u64 = std::mem::size_of::<i64>() as u64;
        match self {
            RequestBody::Sdp(p) => p.n as u64 * CELL,
            RequestBody::Mcm { problem, .. } => {
                // n×n flat arena bound; the split sidecar is u32 per cell
                let cells = (problem.n() as u64).saturating_mul(problem.n() as u64);
                let sidecar = if want_solution { cells * 4 } else { 0 };
                cells.saturating_mul(CELL).saturating_add(sidecar)
            }
            RequestBody::Align(p) => {
                let cells = p.num_cells() as u64;
                // packed 2-bit moves: 4 cells per sidecar byte
                let sidecar = if want_solution { cells.div_ceil(4) } else { 0 };
                cells.saturating_mul(CELL).saturating_add(sidecar)
            }
            RequestBody::Viterbi(p) => {
                // f64 lattice + u32 backpointer sidecar
                let cells = p.num_cells() as u64;
                let sidecar = if want_solution { cells * 4 } else { 0 };
                cells.saturating_mul(CELL).saturating_add(sidecar)
            }
            RequestBody::Cyk(p) => {
                // f64 (span × nonterminal) table + u32 packed-split sidecar
                let cells = p.num_cells() as u64;
                let sidecar = if want_solution { cells * 4 } else { 0 };
                cells.saturating_mul(CELL).saturating_add(sidecar)
            }
            RequestBody::Stats => 0,
        }
    }
}

/// Decode an array of non-negative integers (observation / word indices).
fn usize_vec(v: &Json, key: &str) -> Result<Vec<usize>> {
    v.arr_field(key)?
        .iter()
        .map(|x| {
            x.as_usize()
                .ok_or_else(|| Error::Json(format!("'{key}' has a non-index element")))
        })
        .collect()
}

/// Decode one grammar-rule row `[lhs, sym, (sym,) logp]` of the `cyk`
/// wire kind: `arity` is 4 for binary rules, 3 for lexical rules; the
/// last element is always a lognum.
fn rule_row(row: &Json, arity: usize, what: &str) -> Result<(u32, u32, Option<u32>, f64)> {
    let items = row
        .as_arr()
        .filter(|a| a.len() == arity)
        .ok_or_else(|| Error::Json(format!("'{what}' rules must be rows of {arity}")))?;
    let sym = |i: usize| -> Result<u32> {
        items[i]
            .as_i64()
            .and_then(|x| u32::try_from(x).ok())
            .ok_or_else(|| Error::Json(format!("'{what}' rule symbol {i} is not a u32")))
    };
    let logp = items[arity - 1]
        .as_lognum()
        .ok_or_else(|| Error::Json(format!("'{what}' rule probability is not a lognum")))?;
    let third = if arity == 4 { Some(sym(2)?) } else { None };
    Ok((sym(0)?, sym(1)?, third, logp))
}

impl Request {
    /// Decode one JSON line.
    pub fn decode(line: &str) -> Result<Request> {
        let v = Json::parse(line)?;
        let id = v.i64_field("id")?;
        let backend = match v.get("backend") {
            Some(b) => Backend::parse(b.as_str().unwrap_or("?"))?,
            None => Backend::Auto,
        };
        // absent flags default to false; a *present* flag of the wrong
        // type is a typed error, like the string/scoring fields below
        let bool_field = |key: &str| -> Result<bool> {
            match v.get(key) {
                None => Ok(false),
                Some(x) => x
                    .as_bool()
                    .ok_or_else(|| Error::Json(format!("field '{key}' is not a boolean"))),
            }
        };
        let full = bool_field("full")?;
        let want_solution = bool_field("want_solution")?;
        let stream = bool_field("stream")?;
        // absent means "no deadline"; a *present* field that is not a
        // non-negative integer is a typed error (same contract as flags)
        let deadline_ms = match v.get("deadline_ms") {
            None => None,
            Some(x) => Some(
                x.as_i64()
                    .filter(|&d| d >= 0)
                    .ok_or_else(|| {
                        Error::Json("field 'deadline_ms' is not a non-negative integer".into())
                    })? as u64,
            ),
        };
        let body = match v.str_field("kind")? {
            "sdp" => {
                let n = v.usize_field("n")?;
                let offsets = v.i64_vec_field("offsets")?;
                let op = Op::parse(v.str_field("op")?)?;
                let init = v.i64_vec_field("init")?;
                RequestBody::Sdp(SdpProblem::new(n, offsets, op, init)?)
            }
            "mcm" => {
                let dims = v.i64_vec_field("dims")?;
                let variant = match v.get("variant") {
                    Some(s) => McmVariant::parse(s.as_str().unwrap_or("?"))?,
                    None => McmVariant::Corrected,
                };
                RequestBody::Mcm {
                    problem: McmProblem::new(dims)?,
                    variant,
                }
            }
            "align" => {
                let a = v.i64_vec_field("a")?;
                let b = v.i64_vec_field("b")?;
                let variant = match v.get("variant") {
                    Some(s) => AlignVariant::parse(s.as_str().unwrap_or("?"))?,
                    None => AlignVariant::Lcs,
                };
                let d = AlignScoring::default();
                // absent fields default; *present* fields of the wrong
                // type are typed errors, not silent default substitution
                let field_or = |key: &str, fallback: i64| -> Result<i64> {
                    match v.get(key) {
                        None => Ok(fallback),
                        Some(x) => x.as_i64().ok_or_else(|| {
                            Error::Json(format!("field '{key}' is not an integer"))
                        }),
                    }
                };
                let scoring = AlignScoring {
                    match_s: field_or("match", d.match_s)?,
                    mismatch: field_or("mismatch", d.mismatch)?,
                    gap: field_or("gap", d.gap)?,
                };
                RequestBody::Align(AlignProblem::new(a, b, variant, scoring)?)
            }
            "viterbi" => {
                let s = v.usize_field("states")?;
                let m = v.usize_field("symbols")?;
                let init = v.lognum_vec_field("init")?;
                let trans = v.lognum_vec_field("trans")?;
                let emit = v.lognum_vec_field("emit")?;
                let obs = usize_vec(&v, "obs")?;
                RequestBody::Viterbi(ViterbiProblem::new(s, m, init, trans, emit, obs)?)
            }
            "cyk" => {
                let r = v.usize_field("nonterminals")?;
                let t = v.usize_field("terminals")?;
                let binary = v
                    .arr_field("binary")?
                    .iter()
                    .map(|row| {
                        let (a, b, c, p) = rule_row(row, 4, "binary")?;
                        Ok(CykRule {
                            lhs: a,
                            rhs_b: b,
                            rhs_c: c.expect("arity 4 has a third symbol"),
                            logp: p,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                let lexical = v
                    .arr_field("lexical")?
                    .iter()
                    .map(|row| {
                        let (lhs, term, _, p) = rule_row(row, 3, "lexical")?;
                        Ok((lhs, term, p))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let words = usize_vec(&v, "words")?;
                RequestBody::Cyk(CykProblem::new(r, t, binary, lexical, words)?)
            }
            "stats" => RequestBody::Stats,
            other => return Err(Error::Json(format!("unknown kind '{other}'"))),
        };
        Ok(Request {
            id,
            body,
            backend,
            full,
            want_solution,
            deadline_ms,
            stream,
        })
    }

    /// Encode (client side).
    pub fn encode(&self) -> String {
        let mut fields: Vec<(&str, Json)> = vec![
            ("id", Json::int(self.id)),
            ("backend", Json::str(self.backend.name())),
        ];
        if self.full {
            fields.push(("full", Json::Bool(true)));
        }
        if self.want_solution {
            fields.push(("want_solution", Json::Bool(true)));
        }
        if let Some(d) = self.deadline_ms {
            fields.push(("deadline_ms", Json::int(d as i64)));
        }
        if self.stream {
            fields.push(("stream", Json::Bool(true)));
        }
        match &self.body {
            RequestBody::Sdp(p) => {
                fields.push(("kind", Json::str("sdp")));
                fields.push(("n", Json::int(p.n as i64)));
                fields.push(("offsets", Json::arr(p.offsets.iter().map(|&v| Json::int(v)))));
                fields.push(("op", Json::str(p.op.name())));
                fields.push(("init", Json::arr(p.init.iter().map(|&v| Json::int(v)))));
            }
            RequestBody::Mcm { problem, variant } => {
                fields.push(("kind", Json::str("mcm")));
                fields.push(("dims", Json::arr(problem.dims.iter().map(|&v| Json::int(v)))));
                fields.push(("variant", Json::str(variant.name())));
            }
            RequestBody::Align(p) => {
                fields.push(("kind", Json::str("align")));
                fields.push(("a", Json::arr(p.a.iter().map(|&v| Json::int(v)))));
                fields.push(("b", Json::arr(p.b.iter().map(|&v| Json::int(v)))));
                fields.push(("variant", Json::str(p.variant.name())));
                fields.push(("match", Json::int(p.scoring.match_s)));
                fields.push(("mismatch", Json::int(p.scoring.mismatch)));
                fields.push(("gap", Json::int(p.scoring.gap)));
            }
            RequestBody::Viterbi(p) => {
                fields.push(("kind", Json::str("viterbi")));
                fields.push(("states", Json::int(p.num_states as i64)));
                fields.push(("symbols", Json::int(p.num_symbols as i64)));
                fields.push(("init", Json::arr(p.init.iter().map(|&v| Json::lognum(v)))));
                fields.push(("trans", Json::arr(p.trans.iter().map(|&v| Json::lognum(v)))));
                fields.push(("emit", Json::arr(p.emit.iter().map(|&v| Json::lognum(v)))));
                fields.push(("obs", Json::arr(p.obs.iter().map(|&v| Json::int(v as i64)))));
            }
            RequestBody::Cyk(p) => {
                fields.push(("kind", Json::str("cyk")));
                fields.push(("nonterminals", Json::int(p.num_nonterminals as i64)));
                fields.push(("terminals", Json::int(p.num_terminals as i64)));
                fields.push((
                    "binary",
                    Json::arr(p.binary.iter().map(|r| {
                        Json::arr([
                            Json::int(r.lhs as i64),
                            Json::int(r.rhs_b as i64),
                            Json::int(r.rhs_c as i64),
                            Json::lognum(r.logp),
                        ])
                    })),
                ));
                fields.push((
                    "lexical",
                    Json::arr(p.lexical.iter().map(|&(lhs, term, lp)| {
                        Json::arr([Json::int(lhs as i64), Json::int(term as i64), Json::lognum(lp)])
                    })),
                ));
                fields.push(("words", Json::arr(p.words.iter().map(|&w| Json::int(w as i64)))));
            }
            RequestBody::Stats => fields.push(("kind", Json::str("stats"))),
        }
        Json::obj(fields).to_string()
    }
}

/// A response line.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: i64,
    pub ok: bool,
    /// Scalar summary: MCM optimal cost / last S-DP element.  The
    /// log-space kinds (`viterbi`, `cyk`) report through [`Response::score`]
    /// instead and leave this 0.
    pub value: i64,
    /// Log-space scalar summary (`viterbi` best path / `cyk` best parse
    /// log-probability), carried as a lognum on the wire (`"-inf"`
    /// sentinel — [`Json::lognum`]).
    pub score: Option<f64>,
    /// Full table when requested.
    pub table: Option<Vec<i64>>,
    /// Full log-space table when requested (`viterbi`/`cyk` `full`
    /// replies), each cell a lognum.
    pub ftable: Option<Vec<f64>>,
    /// Which backend actually served it, e.g. "xla:mcm_diagonal_i32_n16".
    pub served_by: String,
    /// Reconstructed solution when the request set `want_solution`
    /// (docs/PROTOCOL.md): `{"parens": …}` for `mcm`,
    /// `{"ops", "pairs", "start", "end", "score"}` for `align`.
    pub solution: Option<Json>,
    pub error: Option<String>,
    /// Typed load-shed marker: the admission gate refused the request
    /// because the worker queue was full.  Distinct from `error` so
    /// clients can retry-with-backoff on overload but not on bad input.
    /// Kept alongside [`ErrorKind::Overloaded`] for wire compatibility:
    /// `overloaded == (error_kind == Some(Overloaded))`.
    pub overloaded: bool,
    /// The typed failure taxonomy (docs/PROTOCOL.md): present on
    /// `timeout` / `panicked` / `too_large` / `overloaded` / `internal`
    /// errors, absent on success and on plain validation errors.
    pub error_kind: Option<ErrorKind>,
    /// Raw stats payload for `kind: stats`.
    pub stats: Option<Json>,
}

impl Response {
    pub fn ok(id: i64, value: i64, served_by: String, table: Option<Vec<i64>>) -> Response {
        Response {
            id,
            ok: true,
            value,
            score: None,
            table,
            ftable: None,
            served_by,
            solution: None,
            error: None,
            overloaded: false,
            error_kind: None,
            stats: None,
        }
    }

    /// Success reply of the log-space kinds (`viterbi`/`cyk`): the scalar
    /// travels as a lognum `score`, `value` stays 0.
    pub fn ok_score(
        id: i64,
        score: f64,
        served_by: String,
        ftable: Option<Vec<f64>>,
    ) -> Response {
        Response {
            score: Some(score),
            ftable,
            ..Response::ok(id, 0, served_by, None)
        }
    }

    pub fn err(id: i64, msg: String) -> Response {
        Response {
            id,
            ok: false,
            value: 0,
            score: None,
            table: None,
            ftable: None,
            served_by: String::new(),
            solution: None,
            error: Some(msg),
            overloaded: false,
            error_kind: None,
            stats: None,
        }
    }

    /// The admission gate's shed reply (DESIGN.md §2).
    pub fn overloaded(id: i64) -> Response {
        Response {
            overloaded: true,
            error_kind: Some(ErrorKind::Overloaded),
            ..Response::err(id, "overloaded".into())
        }
    }

    /// The deadline reply: the request's latency budget expired before or
    /// during the solve.
    pub fn timeout(id: i64) -> Response {
        Response {
            error_kind: Some(ErrorKind::Timeout),
            ..Response::err(id, "deadline exceeded".into())
        }
    }

    /// The panic-isolation reply: the solve panicked and was contained at
    /// the worker-pool boundary; the connection and server stay usable.
    pub fn panicked(id: i64, msg: String) -> Response {
        Response {
            error_kind: Some(ErrorKind::Panicked),
            ..Response::err(id, msg)
        }
    }

    /// The memory-admission reply: the estimated solve footprint exceeds
    /// the server's `max_solve_bytes` budget.
    pub fn too_large(id: i64, msg: String) -> Response {
        Response {
            error_kind: Some(ErrorKind::TooLarge),
            ..Response::err(id, msg)
        }
    }

    /// The certifier-refusal reply (and any other internal-invariant
    /// failure): the schedule the router was about to dispatch did not
    /// carry an admissible certificate, so it was refused instead of
    /// executed (DESIGN.md §10).
    pub fn internal(id: i64, msg: String) -> Response {
        Response {
            error_kind: Some(ErrorKind::Internal),
            ..Response::err(id, msg)
        }
    }

    pub fn encode(&self) -> String {
        Json::obj(self.wire_fields()).to_string()
    }

    /// The reply's wire fields in one place, shared by the unary encoding
    /// ([`Response::encode`]) and the streaming terminal frame
    /// ([`Frame::Result`]), so the two paths cannot drift.
    fn wire_fields(&self) -> Vec<(&str, Json)> {
        let mut fields: Vec<(&str, Json)> = vec![
            ("id", Json::int(self.id)),
            ("ok", Json::Bool(self.ok)),
            ("value", Json::int(self.value)),
            ("served_by", Json::str(self.served_by.clone())),
        ];
        if let Some(s) = self.score {
            fields.push(("score", Json::lognum(s)));
        }
        if let Some(t) = &self.table {
            fields.push(("table", Json::arr(t.iter().map(|&v| Json::int(v)))));
        }
        if let Some(t) = &self.ftable {
            fields.push(("ftable", Json::arr(t.iter().map(|&v| Json::lognum(v)))));
        }
        if let Some(s) = &self.solution {
            fields.push(("solution", s.clone()));
        }
        if let Some(e) = &self.error {
            fields.push(("error", Json::str(e.clone())));
        }
        if self.overloaded {
            fields.push(("overloaded", Json::Bool(true)));
        }
        if let Some(k) = self.error_kind {
            fields.push(("error_kind", Json::str(k.name())));
        }
        if let Some(s) = &self.stats {
            fields.push(("stats", s.clone()));
        }
        fields
    }

    pub fn decode(line: &str) -> Result<Response> {
        let v = Json::parse(line)?;
        Ok(Response {
            id: v.i64_field("id")?,
            ok: v.field("ok")?.as_bool().unwrap_or(false),
            value: v.get("value").and_then(|x| x.as_i64()).unwrap_or(0),
            score: v.get("score").and_then(|x| x.as_lognum()),
            table: match v.get("table") {
                Some(Json::Arr(items)) => Some(
                    items
                        .iter()
                        .map(|x| x.as_i64().unwrap_or(0))
                        .collect(),
                ),
                _ => None,
            },
            ftable: match v.get("ftable") {
                Some(Json::Arr(items)) => Some(
                    items
                        .iter()
                        .map(|x| x.as_lognum().unwrap_or(f64::NAN))
                        .collect(),
                ),
                _ => None,
            },
            served_by: v
                .get("served_by")
                .and_then(|x| x.as_str())
                .unwrap_or("")
                .to_string(),
            solution: v.get("solution").cloned(),
            error: v.get("error").and_then(|x| x.as_str()).map(String::from),
            overloaded: v
                .get("overloaded")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            error_kind: match v.get("error_kind").and_then(|x| x.as_str()) {
                Some(s) => Some(ErrorKind::parse(s)?),
                None => None,
            },
            stats: v.get("stats").cloned(),
        })
    }
}

/// Streamed replies split a large `solution` object across chunks of at
/// most this many bytes of its JSON text (docs/PROTOCOL.md §Streaming).
/// Chunk boundaries always fall on UTF-8 character boundaries, so every
/// chunk is a valid JSON string on the wire.
pub const SOLUTION_CHUNK_BYTES: usize = 2048;

/// One frame of a streamed reply (docs/PROTOCOL.md §Streaming).
///
/// A `stream: true` request is answered by zero or more [`Frame::Progress`]
/// frames, then (when the reply carries a reconstructed solution) one or
/// more [`Frame::SolutionChunk`] frames in `seq` order, then exactly one
/// terminal [`Frame::Result`].  Every frame carries the request `id`, so
/// pipelined streams stay correlated; the terminal frame ends the stream
/// for that id — nothing follows it.
#[derive(Debug, Clone)]
pub enum Frame {
    /// Incremental progress: `supersteps` completed and an estimate of
    /// `cells` finalized so far, sampled at the executor's cancellation
    /// poll sites.  Monotone non-decreasing within one stream.
    Progress { id: i64, supersteps: u64, cells: u64 },
    /// One chunk of the solution object's JSON text.  Concatenating all
    /// chunks of a stream in `seq` order (0-based, dense) reproduces the
    /// exact text the unary path would have put in the reply's `solution`
    /// field; `last` marks the final chunk.
    SolutionChunk {
        id: i64,
        seq: u64,
        last: bool,
        chunk: String,
    },
    /// The terminal frame: the ordinary reply shape plus
    /// `"frame": "result"`.  When the solution travelled as chunks, the
    /// terminal frame omits the inline `solution` field.
    Result(Response),
}

impl Frame {
    /// Encode one frame as a JSON line (without the trailing newline).
    pub fn encode(&self) -> String {
        match self {
            Frame::Progress {
                id,
                supersteps,
                cells,
            } => Json::obj(vec![
                ("id", Json::int(*id)),
                ("frame", Json::str("progress")),
                ("supersteps", Json::int(*supersteps as i64)),
                ("cells", Json::int(*cells as i64)),
            ])
            .to_string(),
            Frame::SolutionChunk {
                id,
                seq,
                last,
                chunk,
            } => {
                let mut fields = vec![
                    ("id", Json::int(*id)),
                    ("frame", Json::str("solution")),
                    ("seq", Json::int(*seq as i64)),
                    ("chunk", Json::str(chunk.clone())),
                ];
                if *last {
                    fields.push(("last", Json::Bool(true)));
                }
                Json::obj(fields).to_string()
            }
            Frame::Result(resp) => {
                let mut fields = resp.wire_fields();
                fields.push(("frame", Json::str("result")));
                Json::obj(fields).to_string()
            }
        }
    }

    /// Decode one reply line of a stream.  A line without a `frame`
    /// marker is an ordinary unary reply (the server answers requests it
    /// could not even parse the `stream` flag out of in the plain shape)
    /// and decodes as a terminal [`Frame::Result`].
    pub fn decode(line: &str) -> Result<Frame> {
        let v = Json::parse(line)?;
        let marker = match v.get("frame") {
            None => return Ok(Frame::Result(Response::decode(line)?)),
            Some(m) => m
                .as_str()
                .ok_or_else(|| Error::Json("field 'frame' is not a string".into()))?,
        };
        let non_negative = |key: &str| -> Result<u64> {
            v.i64_field(key)?
                .try_into()
                .map_err(|_| Error::Json(format!("field '{key}' is negative")))
        };
        match marker {
            "progress" => Ok(Frame::Progress {
                id: v.i64_field("id")?,
                supersteps: non_negative("supersteps")?,
                cells: non_negative("cells")?,
            }),
            "solution" => Ok(Frame::SolutionChunk {
                id: v.i64_field("id")?,
                seq: non_negative("seq")?,
                last: v.get("last").and_then(|x| x.as_bool()).unwrap_or(false),
                chunk: v.str_field("chunk")?.to_string(),
            }),
            "result" => Ok(Frame::Result(Response::decode(line)?)),
            other => Err(Error::Json(format!("unknown frame '{other}'"))),
        }
    }

    /// The frame's request id (all frame shapes carry one).
    pub fn id(&self) -> i64 {
        match self {
            Frame::Progress { id, .. } | Frame::SolutionChunk { id, .. } => *id,
            Frame::Result(resp) => resp.id,
        }
    }
}

/// Split a solution object into its chunked wire frames: the object's
/// JSON text cut at ≤[`SOLUTION_CHUNK_BYTES`] per chunk (always on UTF-8
/// character boundaries), `seq` dense from 0, `last` on the final chunk.
pub fn solution_chunk_frames(id: i64, solution: &Json) -> Vec<Frame> {
    let text = solution.to_string();
    let mut frames = Vec::new();
    let mut rest = text.as_str();
    let mut seq = 0u64;
    loop {
        let mut cut = rest.len().min(SOLUTION_CHUNK_BYTES);
        while !rest.is_char_boundary(cut) {
            cut -= 1;
        }
        let (head, tail) = rest.split_at(cut);
        frames.push(Frame::SolutionChunk {
            id,
            seq,
            last: tail.is_empty(),
            chunk: head.to_string(),
        });
        if tail.is_empty() {
            return frames;
        }
        rest = tail;
        seq += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sdp_request_roundtrip() {
        let p = SdpProblem::fibonacci(16);
        let req = Request {
            id: 7,
            body: RequestBody::Sdp(p),
            backend: Backend::Native,
            full: true,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let line = req.encode();
        let back = Request::decode(&line).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.backend, Backend::Native);
        assert!(back.full);
        match back.body {
            RequestBody::Sdp(p) => {
                assert_eq!(p.n, 16);
                assert_eq!(p.offsets, vec![2, 1]);
            }
            _ => panic!("wrong body"),
        }
    }

    #[test]
    fn mcm_request_roundtrip() {
        let req = Request {
            id: 1,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::PaperFaithful,
            },
            backend: Backend::Auto,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let back = Request::decode(&req.encode()).unwrap();
        match back.body {
            RequestBody::Mcm { problem, variant } => {
                assert_eq!(problem.dims, vec![30, 35, 15, 5, 10, 20, 25]);
                assert_eq!(variant, McmVariant::PaperFaithful);
            }
            _ => panic!("wrong body"),
        }
    }

    #[test]
    fn invalid_requests_rejected() {
        assert!(Request::decode("not json").is_err());
        assert!(Request::decode(r#"{"id": 1}"#).is_err()); // no kind
        assert!(Request::decode(r#"{"id": 1, "kind": "sdp", "n": 10, "offsets": [1, 2], "op": "min", "init": [0]}"#).is_err()); // increasing offsets
        assert!(Request::decode(r#"{"id": 1, "kind": "mcm", "dims": [5]}"#).is_err());
        assert!(Request::decode(r#"{"id": 1, "kind": "wat"}"#).is_err());
        // align: empty sequences and bad variants are typed errors
        assert!(Request::decode(r#"{"id": 1, "kind": "align", "a": [], "b": [1]}"#).is_err());
        assert!(
            Request::decode(r#"{"id": 1, "kind": "align", "a": [1], "b": [1], "variant": "x"}"#)
                .is_err()
        );
        // local alignment with nonsensical scoring is rejected at decode
        assert!(Request::decode(
            r#"{"id": 1, "kind": "align", "a": [1], "b": [1], "variant": "local", "gap": 3}"#
        )
        .is_err());
        // a *present* scoring field of the wrong type must be a typed
        // error, never a silent fall-back to the default
        assert!(Request::decode(
            r#"{"id": 1, "kind": "align", "a": [1], "b": [1], "gap": "-3"}"#
        )
        .is_err());
        assert!(Request::decode(
            r#"{"id": 1, "kind": "align", "a": [1], "b": [1], "match": 2.5}"#
        )
        .is_err());
    }

    #[test]
    fn align_request_roundtrip() {
        let p = AlignProblem::new(
            vec![1, 2, 3, 4],
            vec![2, 3, 9],
            AlignVariant::Local,
            AlignScoring {
                match_s: 3,
                mismatch: -2,
                gap: -1,
            },
        )
        .unwrap();
        let req = Request {
            id: 11,
            body: RequestBody::Align(p),
            backend: Backend::Auto,
            full: true,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        };
        let back = Request::decode(&req.encode()).unwrap();
        assert_eq!(back.id, 11);
        match back.body {
            RequestBody::Align(p) => {
                assert_eq!(p.a, vec![1, 2, 3, 4]);
                assert_eq!(p.b, vec![2, 3, 9]);
                assert_eq!(p.variant, AlignVariant::Local);
                assert_eq!(p.scoring.match_s, 3);
                assert_eq!(p.scoring.mismatch, -2);
                assert_eq!(p.scoring.gap, -1);
            }
            _ => panic!("wrong body"),
        }
    }

    #[test]
    fn align_request_defaults() {
        // variant and scoring default when absent
        let back =
            Request::decode(r#"{"id": 2, "kind": "align", "a": [1, 2], "b": [2]}"#).unwrap();
        match back.body {
            RequestBody::Align(p) => {
                assert_eq!(p.variant, AlignVariant::Lcs);
                assert_eq!(p.scoring, AlignScoring::default());
            }
            _ => panic!("wrong body"),
        }
    }

    #[test]
    fn viterbi_request_roundtrip_with_neg_infinity() {
        let p = ViterbiProblem::new(
            2,
            2,
            vec![(0.5f64).ln(), f64::NEG_INFINITY],
            vec![(0.5f64).ln(); 4],
            vec![(0.5f64).ln(), f64::NEG_INFINITY, (0.25f64).ln(), (0.75f64).ln()],
            vec![0, 1, 1],
        )
        .unwrap();
        let req = Request {
            id: 21,
            body: RequestBody::Viterbi(p),
            backend: Backend::Auto,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: false,
        };
        let line = req.encode();
        assert!(line.contains("\"-inf\""), "−∞ must travel as the sentinel: {line}");
        let back = Request::decode(&line).unwrap();
        match back.body {
            RequestBody::Viterbi(p) => {
                assert_eq!(p.num_states, 2);
                assert_eq!(p.init[1], f64::NEG_INFINITY);
                assert_eq!(p.emit[1], f64::NEG_INFINITY);
                assert_eq!(p.obs, vec![0, 1, 1]);
            }
            _ => panic!("wrong body"),
        }
        // invalid shapes and non-lognum probabilities are typed errors
        assert!(Request::decode(
            r#"{"id": 1, "kind": "viterbi", "states": 1, "symbols": 1, "init": [0], "trans": [0], "emit": [0], "obs": []}"#
        )
        .is_err());
        assert!(Request::decode(
            r#"{"id": 1, "kind": "viterbi", "states": 1, "symbols": 1, "init": ["nan"], "trans": [0], "emit": [0], "obs": [0]}"#
        )
        .is_err());
        // +inf decodes as a lognum but fails problem validation
        assert!(Request::decode(
            r#"{"id": 1, "kind": "viterbi", "states": 1, "symbols": 1, "init": ["inf"], "trans": [0], "emit": [0], "obs": [0]}"#
        )
        .is_err());
    }

    #[test]
    fn cyk_request_roundtrip() {
        let req = Request {
            id: 22,
            body: RequestBody::Cyk(CykProblem::balanced_example(3)),
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: false,
        };
        let back = Request::decode(&req.encode()).unwrap();
        match back.body {
            RequestBody::Cyk(p) => {
                assert_eq!(p.num_nonterminals, 1);
                assert_eq!(p.binary.len(), 1);
                assert_eq!(p.binary[0].lhs, 0);
                assert!((p.binary[0].logp - (0.5f64).ln()).abs() < 1e-12);
                assert_eq!(p.lexical, vec![(0, 0, (0.5f64).ln())]);
                assert_eq!(p.words, vec![0, 0, 0]);
            }
            _ => panic!("wrong body"),
        }
        // malformed rule rows are typed errors
        for bad in [
            r#"{"id": 1, "kind": "cyk", "nonterminals": 1, "terminals": 1, "binary": [[0, 0, -0.7]], "lexical": [], "words": [0]}"#,
            r#"{"id": 1, "kind": "cyk", "nonterminals": 1, "terminals": 1, "binary": [], "lexical": [[0, "x", -0.7]], "words": [0]}"#,
            r#"{"id": 1, "kind": "cyk", "nonterminals": 1, "terminals": 1, "binary": [], "lexical": [[0, 0, -0.7]], "words": [-1]}"#,
        ] {
            assert!(Request::decode(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn score_and_ftable_roundtrip_as_lognums() {
        let r = Response::ok_score(
            31,
            f64::NEG_INFINITY,
            "native:viterbi_lattice[fused]".into(),
            Some(vec![0.0, f64::NEG_INFINITY, -2.5]),
        );
        let line = r.encode();
        assert!(line.contains("\"-inf\""), "{line}");
        let back = Response::decode(&line).unwrap();
        assert!(back.ok);
        assert_eq!(back.value, 0);
        assert_eq!(back.score, Some(f64::NEG_INFINITY));
        assert_eq!(back.ftable.unwrap(), vec![0.0, f64::NEG_INFINITY, -2.5]);
        // integer kinds never carry a score
        let plain = Response::decode(&Response::ok(1, 7, "x".into(), None).encode()).unwrap();
        assert_eq!(plain.score, None);
        assert!(plain.ftable.is_none());
    }

    #[test]
    fn want_solution_roundtrip_and_default() {
        let req = Request {
            id: 4,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::Corrected,
            },
            backend: Backend::Auto,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: false,
        };
        let line = req.encode();
        assert!(line.contains("want_solution"), "{line}");
        let back = Request::decode(&line).unwrap();
        assert!(back.want_solution);
        // absent field defaults to false
        let plain = Request::decode(r#"{"id": 1, "kind": "mcm", "dims": [2, 3, 4]}"#).unwrap();
        assert!(!plain.want_solution);
        // a *present* flag of the wrong type is a typed error, never a
        // silent false (docs/PROTOCOL.md)
        assert!(Request::decode(
            r#"{"id": 1, "kind": "mcm", "dims": [2, 3, 4], "want_solution": 1}"#
        )
        .is_err());
        assert!(Request::decode(
            r#"{"id": 1, "kind": "mcm", "dims": [2, 3, 4], "full": "yes"}"#
        )
        .is_err());
    }

    #[test]
    fn solution_field_roundtrip() {
        let mut r = Response::ok(8, 64, "native:mcm_pipeline_corrected[fused]".into(), None);
        r.solution = Some(Json::obj(vec![("parens", Json::str("((A1A2)A3)"))]));
        let back = Response::decode(&r.encode()).unwrap();
        let sol = back.solution.expect("solution survives the wire");
        assert_eq!(sol.str_field("parens").unwrap(), "((A1A2)A3)");
        // absent stays absent
        let bare = Response::decode(&Response::ok(1, 0, "x".into(), None).encode()).unwrap();
        assert!(bare.solution.is_none());
    }

    #[test]
    fn response_roundtrip() {
        let r = Response::ok(3, 15125, "xla:mcm_diagonal_i32_n8".into(), Some(vec![1, 2, 3]));
        let back = Response::decode(&r.encode()).unwrap();
        assert!(back.ok);
        assert_eq!(back.value, 15125);
        assert_eq!(back.table.unwrap(), vec![1, 2, 3]);
        assert_eq!(back.served_by, "xla:mcm_diagonal_i32_n8");
    }

    #[test]
    fn error_response_roundtrip() {
        let r = Response::err(9, "no bucket".into());
        let back = Response::decode(&r.encode()).unwrap();
        assert!(!back.ok);
        assert!(!back.overloaded);
        assert_eq!(back.error.unwrap(), "no bucket");
    }

    #[test]
    fn overloaded_response_roundtrip() {
        let r = Response::overloaded(12);
        let back = Response::decode(&r.encode()).unwrap();
        assert_eq!(back.id, 12);
        assert!(!back.ok);
        assert!(back.overloaded, "shed replies must stay typed on the wire");
        assert_eq!(back.error.unwrap(), "overloaded");
        assert_eq!(back.error_kind, Some(ErrorKind::Overloaded));
    }

    #[test]
    fn deadline_ms_roundtrip_and_validation() {
        let mut req = Request {
            id: 5,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::Corrected,
            },
            backend: Backend::Auto,
            full: false,
            want_solution: false,
            deadline_ms: Some(250),
            stream: false,
        };
        let line = req.encode();
        assert!(line.contains("deadline_ms"), "{line}");
        assert_eq!(Request::decode(&line).unwrap().deadline_ms, Some(250));
        // absent means no deadline and is not emitted
        req.deadline_ms = None;
        let line = req.encode();
        assert!(!line.contains("deadline_ms"), "{line}");
        assert_eq!(Request::decode(&line).unwrap().deadline_ms, None);
        // a *present* deadline of the wrong shape is a typed error
        for bad in [
            r#"{"id": 1, "kind": "stats", "deadline_ms": -5}"#,
            r#"{"id": 1, "kind": "stats", "deadline_ms": "soon"}"#,
            r#"{"id": 1, "kind": "stats", "deadline_ms": 1.5}"#,
        ] {
            assert!(Request::decode(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn error_kind_taxonomy_roundtrips() {
        let cases: [(Response, ErrorKind, &str); 4] = [
            (Response::timeout(1), ErrorKind::Timeout, "timeout"),
            (
                Response::panicked(2, "solver panicked".into()),
                ErrorKind::Panicked,
                "panicked",
            ),
            (
                Response::too_large(3, "estimated 9GiB > budget".into()),
                ErrorKind::TooLarge,
                "too_large",
            ),
            (
                Response::internal(6, "mcm schedule refused by certifier".into()),
                ErrorKind::Internal,
                "internal",
            ),
        ];
        for (r, kind, name) in cases {
            let line = r.encode();
            assert!(line.contains(name), "{line}");
            let back = Response::decode(&line).unwrap();
            assert!(!back.ok);
            assert!(!back.overloaded);
            assert_eq!(back.error_kind, Some(kind));
            assert!(back.error.is_some());
        }
        // ok replies and plain validation errors carry no kind
        let ok = Response::decode(&Response::ok(4, 1, "x".into(), None).encode()).unwrap();
        assert_eq!(ok.error_kind, None);
        let plain = Response::decode(&Response::err(5, "bad input".into()).encode()).unwrap();
        assert_eq!(plain.error_kind, None);
        // unknown kinds on the wire are decode errors, not silent None
        assert!(Response::decode(r#"{"id": 1, "ok": false, "error_kind": "melted"}"#).is_err());
        // retry guidance: too_large and internal are structurally
        // unretryable — the identical request fails the same way again
        assert!(ErrorKind::Timeout.retryable());
        assert!(ErrorKind::Overloaded.retryable());
        assert!(ErrorKind::Panicked.retryable());
        assert!(!ErrorKind::TooLarge.retryable());
        assert!(!ErrorKind::Internal.retryable());
    }

    #[test]
    fn stream_flag_roundtrip_and_typed_error() {
        let mut req = Request {
            id: 6,
            body: RequestBody::Mcm {
                problem: McmProblem::clrs(),
                variant: McmVariant::Corrected,
            },
            backend: Backend::Auto,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: true,
        };
        let line = req.encode();
        assert!(line.contains("\"stream\""), "{line}");
        assert!(Request::decode(&line).unwrap().stream);
        // absent defaults to false and is not emitted
        req.stream = false;
        let line = req.encode();
        assert!(!line.contains("\"stream\""), "{line}");
        assert!(!Request::decode(&line).unwrap().stream);
        // a *present* flag of the wrong type is a typed error
        assert!(
            Request::decode(r#"{"id": 1, "kind": "stats", "stream": "yes"}"#).is_err()
        );
    }

    #[test]
    fn progress_frame_roundtrip() {
        let f = Frame::Progress {
            id: 9,
            supersteps: 12,
            cells: 4096,
        };
        let line = f.encode();
        assert!(line.contains("\"frame\":\"progress\""), "{line}");
        match Frame::decode(&line).unwrap() {
            Frame::Progress {
                id,
                supersteps,
                cells,
            } => {
                assert_eq!((id, supersteps, cells), (9, 12, 4096));
            }
            other => panic!("wrong frame: {other:?}"),
        }
        assert_eq!(f.id(), 9);
        // malformed frames are typed errors, not silent results
        assert!(Frame::decode(r#"{"id": 1, "frame": "progress"}"#).is_err());
        assert!(Frame::decode(r#"{"id": 1, "frame": "melted"}"#).is_err());
        assert!(Frame::decode(r#"{"id": 1, "frame": 7}"#).is_err());
    }

    #[test]
    fn solution_chunks_reassemble_exactly() {
        // a solution bigger than one chunk: chunks are dense, ordered,
        // last-marked, and concatenate to the exact unary JSON text
        let big = Json::obj(vec![(
            "ops",
            Json::str("M".repeat(3 * SOLUTION_CHUNK_BYTES)),
        )]);
        let frames = solution_chunk_frames(5, &big);
        assert!(frames.len() >= 3, "{} chunks", frames.len());
        let mut text = String::new();
        for (i, f) in frames.iter().enumerate() {
            let back = Frame::decode(&f.encode()).unwrap();
            match back {
                Frame::SolutionChunk {
                    id,
                    seq,
                    last,
                    chunk,
                } => {
                    assert_eq!(id, 5);
                    assert_eq!(seq, i as u64);
                    assert_eq!(last, i + 1 == frames.len());
                    assert!(chunk.len() <= SOLUTION_CHUNK_BYTES);
                    text.push_str(&chunk);
                }
                other => panic!("wrong frame: {other:?}"),
            }
        }
        assert_eq!(text, big.to_string());
        assert_eq!(Json::parse(&text).unwrap(), big);
        // a small solution is exactly one last-marked chunk
        let small = solution_chunk_frames(1, &Json::obj(vec![("parens", Json::str("(A1)"))]));
        assert_eq!(small.len(), 1);
        assert!(matches!(
            &small[0],
            Frame::SolutionChunk { last: true, seq: 0, .. }
        ));
    }

    #[test]
    fn result_frame_matches_unary_encoding() {
        // the terminal frame is the unary reply plus the marker: decoding
        // it as a Response must agree field-for-field (shared encoder)
        let mut r = Response::ok(3, 15125, "native:mcm_pipeline_corrected[fused]".into(), None);
        r.solution = Some(Json::obj(vec![("parens", Json::str("(A1A2)"))]));
        let line = Frame::Result(r.clone()).encode();
        assert!(line.contains("\"frame\":\"result\""), "{line}");
        match Frame::decode(&line).unwrap() {
            Frame::Result(back) => {
                assert_eq!(back.id, r.id);
                assert_eq!(back.value, r.value);
                assert_eq!(back.served_by, r.served_by);
                assert_eq!(
                    back.solution.unwrap().str_field("parens").unwrap(),
                    "(A1A2)"
                );
            }
            other => panic!("wrong frame: {other:?}"),
        }
        // a frame-less line is a terminal unary reply, so clients that
        // streamed a request the server failed to parse still terminate
        let plain = Response::err(0, "bad json".into()).encode();
        assert!(matches!(Frame::decode(&plain).unwrap(), Frame::Result(_)));
    }

    #[test]
    fn estimated_solve_bytes_tracks_table_and_sidecar() {
        let sdp = RequestBody::Sdp(SdpProblem::fibonacci(16));
        assert_eq!(sdp.estimated_solve_bytes(false), 16 * 8);
        let mcm = RequestBody::Mcm {
            problem: McmProblem::clrs(), // n = 6
            variant: McmVariant::Corrected,
        };
        assert_eq!(mcm.estimated_solve_bytes(false), 36 * 8);
        assert_eq!(mcm.estimated_solve_bytes(true), 36 * 8 + 36 * 4);
        let align = RequestBody::Align(
            AlignProblem::lcs(vec![1, 2, 3], vec![4, 5]).unwrap(), // 4×3 cells
        );
        assert_eq!(align.estimated_solve_bytes(false), 12 * 8);
        assert_eq!(align.estimated_solve_bytes(true), 12 * 8 + 3);
        let vit = RequestBody::Viterbi(
            ViterbiProblem::new(2, 1, vec![0.0; 2], vec![0.0; 4], vec![0.0; 2], vec![0, 0, 0])
                .unwrap(), // 3×2 lattice
        );
        assert_eq!(vit.estimated_solve_bytes(false), 6 * 8);
        assert_eq!(vit.estimated_solve_bytes(true), 6 * 8 + 6 * 4);
        let cyk = RequestBody::Cyk(CykProblem::balanced_example(3)); // 6 spans × 1 NT
        assert_eq!(cyk.estimated_solve_bytes(false), 6 * 8);
        assert_eq!(cyk.estimated_solve_bytes(true), 6 * 8 + 6 * 4);
        assert_eq!(RequestBody::Stats.estimated_solve_bytes(true), 0);
    }
}
