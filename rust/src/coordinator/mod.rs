//! The serving coordinator: a DP-solving service in the shape of a
//! vLLM-style router (DESIGN.md §2).
//!
//! ```text
//! TCP (line-delimited JSON)            coordinator
//!   conn threads ──► request queue ──► batcher ──► worker pool ──► backend
//!                                                     │              ├ native rust solvers
//!        responses ◄── per-request channels ◄─────────┘              ├ PJRT engine (batched)
//!                                                                    └ GPU cost simulator
//! ```
//!
//! * [`request`] — wire protocol types + JSON codec (incl. the typed
//!   `overloaded` load-shed reply).
//! * [`router`] — backend selection (native / XLA bucket / simulator).
//! * [`batcher`] — dynamic batching: group compatible requests within a
//!   deadline window (deadline min-heap, flushed every loop iteration)
//!   so one PJRT dispatch serves many requests; admission-gates against
//!   the worker queue bound.
//! * [`pool`] — the worker thread pool (bounded queue).
//! * [`metrics`] — latency histograms and throughput counters.
//! * [`server`] — the TCP server (tracked, drainable connections) and a
//!   blocking client.
//! * `reactor` (Linux) — the optional epoll front end: one event-loop
//!   thread owns every socket, with framed read/write buffers that
//!   tolerate partial I/O at any byte boundary.

pub mod batcher;
pub mod metrics;
pub mod pool;
#[cfg(target_os = "linux")]
pub mod reactor;
pub mod request;
pub mod router;
pub mod server;
