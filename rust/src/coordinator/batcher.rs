//! Dynamic batching: hold compatible requests for up to `max_wait` (or
//! until `max_batch` accumulate) so one PJRT dispatch serves many — the
//! same policy a serving router applies to model invocations.
//!
//! Deadlines are tracked in a min-heap keyed by `enqueued + max_wait`
//! (one entry per group creation, lazily invalidated), and expired groups
//! are flushed on **every** loop iteration — not only when the request
//! channel goes quiet.  The seed flushed deadlines only from the
//! `recv_timeout` timeout arm, so a steady trickle of traffic to *other*
//! group keys could starve a partial batch far past its deadline.
//!
//! Admission is gated before anything enters the batcher: when the worker
//! pool's bounded queue is full, `submit_request` sheds the request with a
//! typed `overloaded` reply (and a `shed` metrics tick) instead of
//! queueing it without bound (DESIGN.md §2).
//!
//! Schedule compilation is *not* part of the dispatch cost the batcher
//! amortizes: every execution path it flushes into (native MCM solve,
//! XLA schedule-executor dispatch) fetches its schedule from the
//! process-wide cache ([`crate::core::cache`]), so only the first request
//! per `(kind, n, variant)` in the process lifetime compiles one, and the
//! server warmup pre-warms the cache for every registered bucket.
//!
//! The batcher is **sharded by wire-kind family**: one thread, pending
//! map, and deadline min-heap per family ([`shard_of`]), so a burst of
//! MCM traffic scans and wakes only the MCM shard — align/viterbi/cyk
//! queues are untouched.  Admission (memory bound, in-flight gate) stays
//! global in [`Batcher::submit_request`]; only post-admission queueing is
//! sharded.
//!
//! Replies leave through a [`ReplySink`]: decoded [`Response`] values for
//! the legacy blocking writer, or pre-encoded wire lines for sinks that
//! can interleave streaming frames.  A request with `stream: true` on a
//! frame-capable sink gets incremental `progress` frames (fed from the
//! executors' cancellation poll sites via [`Progress`]), its solution as
//! chunked `solution` frames, and a terminal `result` frame —
//! docs/PROTOCOL.md "Streaming".

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::request::{solution_chunk_frames, Frame, Request, RequestBody, Response};
use crate::coordinator::router::{group_key, GroupKey, Route, Router, SolveControls};
use crate::runtime::exec_pool::Progress;

/// Where a reply goes.  The blocking per-connection writer consumes
/// decoded [`Response`] values; line-oriented sinks carry pre-encoded
/// wire lines, so streaming `progress` / `solution` / `result` frames
/// travel the same ordered channel as unary replies.
#[derive(Clone)]
pub enum ReplySink {
    /// Decoded responses (legacy blocking writer, in-process tests).
    /// Cannot carry frames: streamed requests degrade to unary here.
    Response(mpsc::Sender<Response>),
    /// Pre-encoded wire lines (newline excluded) for a writer that owns
    /// the socket, e.g. the blocking server's per-connection writer.
    Line(mpsc::Sender<String>),
    /// Reactor-owned connection: lines are tagged with the connection id
    /// (and whether they terminate a request, so the reactor can retire
    /// half-closed connections) and the reactor is woken to drain its
    /// completion queue.
    Reactor {
        conn: u64,
        tx: mpsc::Sender<(u64, String, bool)>,
        wake: Arc<dyn Fn() + Send + Sync>,
    },
}

impl ReplySink {
    /// Whether this sink can carry streaming frames; [`ReplySink::Response`]
    /// cannot, so streamed requests degrade to a unary reply there.
    pub fn supports_frames(&self) -> bool {
        !matches!(self, ReplySink::Response(_))
    }

    /// Deliver a terminal unary response.
    pub fn send_response(&self, resp: Response) {
        match self {
            ReplySink::Response(tx) => {
                let _ = tx.send(resp);
            }
            ReplySink::Line(tx) => {
                let _ = tx.send(resp.encode());
            }
            ReplySink::Reactor { conn, tx, wake } => {
                let _ = tx.send((*conn, resp.encode(), true));
                (**wake)();
            }
        }
    }

    /// Deliver one streaming frame.  On a [`ReplySink::Response`] sink
    /// only the terminal `Result` frame is representable; progress and
    /// solution chunks are dropped (the caller keeps the full payload in
    /// the result for that case — see [`deliver`]).
    pub fn send_frame(&self, frame: Frame) {
        match self {
            ReplySink::Response(tx) => {
                if let Frame::Result(resp) = frame {
                    let _ = tx.send(resp);
                }
            }
            ReplySink::Line(tx) => {
                let _ = tx.send(frame.encode());
            }
            ReplySink::Reactor { conn, tx, wake } => {
                let terminal = matches!(frame, Frame::Result(_));
                let _ = tx.send((*conn, frame.encode(), terminal));
                (**wake)();
            }
        }
    }
}

impl From<mpsc::Sender<Response>> for ReplySink {
    fn from(tx: mpsc::Sender<Response>) -> ReplySink {
        ReplySink::Response(tx)
    }
}

impl From<mpsc::Sender<String>> for ReplySink {
    fn from(tx: mpsc::Sender<String>) -> ReplySink {
        ReplySink::Line(tx)
    }
}

/// A request waiting for dispatch, with its reply channel.
pub struct Pending {
    pub request: Request,
    pub route: Route,
    pub enqueued: Instant,
    /// Absolute deadline derived from the request's `deadline_ms` at
    /// admission; `None` = unbounded.  Entries past it are shed from the
    /// flush with a typed `timeout` reply instead of being solved, and
    /// live ones thread it into the executors' cancel tokens.
    pub deadline: Option<Instant>,
    pub reply: ReplySink,
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct Policy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for Policy {
    fn default() -> Self {
        Policy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// What flows to a batcher shard thread: requests, or the drain signal.
enum Msg {
    Req(Box<Pending>),
    Stop,
}

/// Number of batcher shards — one per wire-kind family.
pub const NUM_SHARDS: usize = 5;

/// Shard index for a request body: each kind family gets its own batcher
/// thread, pending map, and deadline heap, so MCM traffic never scans
/// align/viterbi/cyk queues.  `Stats` is answered inline by connections
/// and normally never reaches the batcher; it maps to the S-DP shard.
pub fn shard_of(body: &RequestBody) -> usize {
    match body {
        RequestBody::Sdp(_) | RequestBody::Stats => 0,
        RequestBody::Mcm { .. } => 1,
        RequestBody::Align(_) => 2,
        RequestBody::Viterbi(_) => 3,
        RequestBody::Cyk(_) => 4,
    }
}

/// The sharded batcher: one thread per kind family, each owning its own
/// pending map + deadline heap, all flushing into one worker pool.
pub struct Batcher {
    /// Per-shard request channels, indexed by [`shard_of`].
    txs: Vec<mpsc::Sender<Msg>>,
    router: Arc<Router>,
    pool: Arc<WorkerPool>,
    metrics: Arc<Metrics>,
    /// Memory admission bound (bytes of estimated solve footprint);
    /// 0 = unlimited.  Checked in [`Batcher::submit_request`] *before*
    /// the in-flight slot claim — an oversized request is refused with a
    /// typed `too_large` reply and never allocates a table.
    max_solve_bytes: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Batcher {
    pub fn start(
        router: Arc<Router>,
        pool: Arc<WorkerPool>,
        metrics: Arc<Metrics>,
        policy: Policy,
    ) -> Batcher {
        Batcher::start_with_limit(router, pool, metrics, policy, 0)
    }

    /// [`Batcher::start`] with a memory admission bound (0 = unlimited).
    pub fn start_with_limit(
        router: Arc<Router>,
        pool: Arc<WorkerPool>,
        metrics: Arc<Metrics>,
        policy: Policy,
        max_solve_bytes: usize,
    ) -> Batcher {
        let mut txs = Vec::with_capacity(NUM_SHARDS);
        let mut handles = Vec::with_capacity(NUM_SHARDS);
        for shard in 0..NUM_SHARDS {
            let (tx, rx) = mpsc::channel::<Msg>();
            let router = router.clone();
            let pool = pool.clone();
            let metrics = metrics.clone();
            let policy = policy.clone();
            let handle = std::thread::Builder::new()
                .name(format!("pipedp-batcher-{shard}"))
                .spawn(move || run(rx, router, pool, metrics, policy))
                .expect("spawn batcher shard");
            txs.push(tx);
            handles.push(handle);
        }
        Batcher {
            txs,
            router,
            pool,
            metrics,
            max_solve_bytes,
            handles: Mutex::new(handles),
        }
    }

    /// Hand a pre-routed request to the batcher, counting it in flight
    /// (the slot is released when its reply is sent), so direct
    /// submissions and gate-admitted ones share one accounting and the
    /// admission bound stays honest under mixed use.  `false` means the
    /// batcher thread is gone and the pending (with its reply sender)
    /// was dropped — the connection sees a disconnect for that request.
    pub fn submit(&self, pending: Pending) -> bool {
        self.metrics.inflight.fetch_add(1, Ordering::Relaxed);
        self.enqueue(pending)
    }

    /// Send a pending to its kind-family shard; the in-flight slot is
    /// already claimed, and on a dead shard thread it is released here.
    fn enqueue(&self, pending: Pending) -> bool {
        let shard = shard_of(&pending.request.body);
        let ok = self.txs[shard].send(Msg::Req(Box::new(pending))).is_ok();
        if !ok {
            self.metrics.dec_inflight();
        }
        ok
    }

    /// Route + enqueue; routing failures answer immediately, and a
    /// saturated coordinator sheds with a typed `overloaded` reply.
    ///
    /// The admission gate bounds *total requests in flight* (batcher
    /// channel + pending groups + worker queue + executing) by the worker
    /// queue capacity — gating on the pool backlog alone would let a
    /// fast-arriving burst hide in the batcher's channel and bypass the
    /// bound.  The backlog check stays as a second trigger for work that
    /// enters the pool without passing this gate.
    pub fn submit_request(&self, request: Request, reply: impl Into<ReplySink>) {
        let reply: ReplySink = reply.into();
        let stream = request.stream;
        // memory admission: a statically-oversized request is refused
        // before claiming anything — load cannot make it admissible
        let est = request.body.estimated_solve_bytes(request.want_solution);
        if self.max_solve_bytes > 0 && est > self.max_solve_bytes as u64 {
            self.metrics.rejected_too_large.fetch_add(1, Ordering::Relaxed);
            deliver_terminal(
                &reply,
                stream,
                Response::too_large(
                    request.id,
                    format!(
                        "estimated solve footprint {est} B exceeds the admission \
                         bound {} B",
                        self.max_solve_bytes
                    ),
                ),
            );
            return;
        }
        let cap = self.pool.capacity();
        // reserve-then-check: the fetch_add atomically claims an in-flight
        // slot, so concurrent connection threads cannot jointly race a
        // load-then-increment past the bound; a failed claim is undone
        let saturated = if self.pool.backlog() >= cap {
            true
        } else if self.metrics.inflight.fetch_add(1, Ordering::Relaxed) >= cap as u64 {
            self.metrics.dec_inflight();
            true
        } else {
            false
        };
        if saturated {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            deliver_terminal(&reply, stream, Response::overloaded(request.id));
            return;
        }
        match self.router.route(&request) {
            // the claimed slot is released when the reply is sent (flush) —
            // see Metrics::dec_inflight for the saturating contract
            Ok(route) => {
                let request_id = request.id;
                let reply2 = reply.clone();
                let now = Instant::now();
                // the budget clock starts at admission: deadline_ms is
                // relative to arrival, converted once to an absolute
                // Instant that queue, shed, and executors all compare to
                // checked_add: an astronomically large budget saturates to
                // "unbounded" instead of panicking on Instant overflow
                let deadline = request
                    .deadline_ms
                    .and_then(|ms| now.checked_add(Duration::from_millis(ms)));
                // enqueue, not submit: the gate's fetch_add above already
                // claimed this request's slot, and enqueue releases it if
                // the batcher thread is gone (else the gauge would ratchet
                // to cap and shed forever)
                let accepted = self.enqueue(Pending {
                    request,
                    route,
                    enqueued: now,
                    deadline,
                    reply,
                });
                if !accepted {
                    deliver_terminal(
                        &reply2,
                        stream,
                        Response::err(request_id, "batcher unavailable".to_string()),
                    );
                }
            }
            Err(e) => {
                deliver_terminal(&reply, stream, Response::err(request.id, e.to_string()));
                self.metrics.dec_inflight(); // answered now: not in flight
            }
        }
    }

    /// Drain every shard's pending groups into the pool and join all
    /// shard threads.  Idempotent; `Drop` calls it too.
    pub fn shutdown(&self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Idle wait when no group holds a deadline.
const IDLE_WAIT: Duration = Duration::from_millis(50);

fn run(
    rx: mpsc::Receiver<Msg>,
    router: Arc<Router>,
    pool: Arc<WorkerPool>,
    metrics: Arc<Metrics>,
    policy: Policy,
) {
    let mut groups: HashMap<GroupKey, Vec<Pending>> = HashMap::new();
    // Min-heap of (deadline, key).  One entry is pushed per group
    // *creation*; entries whose group was since flushed (a re-created
    // group pushes its own fresh entry) are dropped lazily on surfacing.
    let mut deadlines: BinaryHeap<Reverse<(Instant, GroupKey)>> = BinaryHeap::new();
    loop {
        // flush everything past its deadline on every iteration — a busy
        // receive stream must never postpone another group's deadline
        flush_expired(
            &mut groups,
            &mut deadlines,
            &router,
            &pool,
            &metrics,
            policy.max_wait,
        );
        // after flush_expired the heap top (if any) is live and in the
        // future, so it is exactly the next wake-up time
        let timeout = match deadlines.peek() {
            Some(Reverse((at, _))) => at
                .saturating_duration_since(Instant::now())
                .max(Duration::from_micros(50)),
            None => IDLE_WAIT,
        };
        match rx.recv_timeout(timeout) {
            Ok(Msg::Req(p)) => {
                let p = *p;
                let key = group_key(&p.request, p.route);
                // Single keys can never grow — dispatch immediately rather
                // than paying the batching window for nothing.
                if matches!(key, GroupKey::Single(_)) {
                    flush(vec![p], &router, &pool, &metrics);
                    continue;
                }
                let group = groups.entry(key.clone()).or_default();
                if group.is_empty() {
                    // first pending defines the group deadline (arrivals
                    // are appended, so index 0 stays the oldest)
                    deadlines.push(Reverse((p.enqueued + policy.max_wait, key.clone())));
                }
                group.push(p);
                if group.len() >= policy.max_batch {
                    let batch = groups.remove(&key).unwrap();
                    flush(batch, &router, &pool, &metrics);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Ok(Msg::Stop) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                for (_, batch) in groups.drain() {
                    flush(batch, &router, &pool, &metrics);
                }
                return;
            }
        }
    }
}

/// Pop and flush every group whose deadline has passed.  Stale heap
/// entries — the group was flushed by size, whether or not a later
/// re-creation (with its own fresh entry) exists — are discarded here,
/// so on return the heap top is a live, future deadline.
fn flush_expired(
    groups: &mut HashMap<GroupKey, Vec<Pending>>,
    deadlines: &mut BinaryHeap<Reverse<(Instant, GroupKey)>>,
    router: &Arc<Router>,
    pool: &Arc<WorkerPool>,
    metrics: &Arc<Metrics>,
    max_wait: Duration,
) {
    let now = Instant::now();
    loop {
        let (at, key) = match deadlines.peek() {
            Some(Reverse((at, key))) => (*at, key.clone()),
            None => return,
        };
        let live = match groups.get(&key) {
            // group already flushed: drop the stale entry
            None => {
                deadlines.pop();
                continue;
            }
            Some(g) => g[0].enqueued + max_wait,
        };
        if live > at {
            // the key was flushed by size and re-created since this entry
            // was pushed; the re-creation pushed its own (later) entry,
            // so this stale one is simply dropped
            deadlines.pop();
            continue;
        }
        if at > now {
            return; // heap top is live and future — nothing else expired
        }
        deadlines.pop();
        let batch = groups.remove(&key).unwrap();
        flush(batch, router, pool, metrics);
    }
}

/// Best-effort text of a caught panic payload (`&str` and `String` cover
/// every `panic!` in this crate; anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Terminal delivery for refusals that happen before a [`Pending`]
/// exists: a streamed request on a frame-capable sink gets its typed
/// error as a `result` frame (so the stream terminates per protocol),
/// everything else gets the plain unary reply.
pub(crate) fn deliver_terminal(sink: &ReplySink, stream: bool, resp: Response) {
    if stream && sink.supports_frames() {
        sink.send_frame(Frame::Result(resp));
    } else {
        sink.send_response(resp);
    }
}

/// Terminal delivery honouring the request's streaming mode: a streamed
/// request on a frame-capable sink gets its solution as chunked
/// `solution` frames followed by a `result` frame with the inline
/// `solution` field elided (the chunks are the payload); everything
/// else — unary requests, and streamed ones whose sink cannot carry
/// frames — gets the plain reply with the solution inline.
fn deliver(p: &Pending, mut resp: Response) {
    if p.request.stream && p.reply.supports_frames() {
        if let Some(sol) = resp.solution.take() {
            for frame in solution_chunk_frames(resp.id, &sol) {
                p.reply.send_frame(frame);
            }
        }
        p.reply.send_frame(Frame::Result(resp));
    } else {
        p.reply.send_response(resp);
    }
}

/// Superstep / cell totals a streamed solve reports progress against.
/// Supersteps mirror each kind's schedule depth — the wavefront count
/// the executors' cancellation poll sites tick through — and cells the
/// DP table size, so `progress` frames interpolate sensibly.
fn progress_goals(body: &RequestBody) -> (u64, u64) {
    match body {
        RequestBody::Sdp(p) => (p.n as u64, p.n as u64),
        RequestBody::Mcm { problem, .. } => {
            let n = problem.n() as u64;
            (n.saturating_sub(1), n.saturating_mul(n))
        }
        RequestBody::Align(p) => (
            (p.rows() + p.cols()).saturating_sub(1) as u64,
            p.num_cells() as u64,
        ),
        RequestBody::Viterbi(p) => (p.obs.len() as u64, p.num_cells() as u64),
        RequestBody::Cyk(p) => (p.n() as u64, p.num_cells() as u64),
        RequestBody::Stats => (0, 0),
    }
}

/// Build the per-request [`SolveControls`]: the admission deadline, plus
/// — for streamed requests on frame-capable sinks — a [`Progress`]
/// observer whose sink encodes `progress` frames straight into the
/// request's reply channel.
fn controls_for(p: &Pending) -> SolveControls {
    let progress = if p.request.stream && p.reply.supports_frames() {
        let sink = p.reply.clone();
        let id = p.request.id;
        let (total_supersteps, total_cells) = progress_goals(&p.request.body);
        Some(Arc::new(Progress::new(
            total_supersteps,
            total_cells,
            Box::new(move |supersteps, cells| {
                sink.send_frame(Frame::Progress { id, supersteps, cells });
            }),
        )))
    } else {
        None
    };
    SolveControls {
        deadline: p.deadline,
        progress,
    }
}

fn flush(batch: Vec<Pending>, router: &Arc<Router>, pool: &Arc<WorkerPool>, metrics: &Arc<Metrics>) {
    if batch.is_empty() {
        return;
    }
    let router = router.clone();
    let metrics = metrics.clone();
    metrics.record_batch(batch.len());
    pool.submit(move || {
        for p in &batch {
            metrics.queue_wait.record(p.enqueued.elapsed());
        }
        // shed entries whose deadline passed while queued: a typed
        // `timeout` reply now is strictly better than a solve whose
        // answer nobody is waiting for (and whose table still costs RAM)
        let now = Instant::now();
        let (expired, live): (Vec<Pending>, Vec<Pending>) = batch
            .into_iter()
            .partition(|p| p.deadline.is_some_and(|d| d <= now));
        for p in expired {
            metrics.timeouts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            metrics
                .errors
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            metrics.latency.record(p.enqueued.elapsed());
            deliver(&p, Response::timeout(p.request.id));
            metrics.dec_inflight();
        }
        if live.is_empty() {
            return;
        }
        let route = live[0].route;
        let reqs: Vec<Request> = live.iter().map(|p| p.request.clone()).collect();
        let controls: Vec<SolveControls> = live.iter().map(controls_for).collect();
        // isolation boundary: an executor panic (a bug, or an injected
        // fault) must answer every request in the group with a typed,
        // id-correlated `panicked` reply instead of dropping the reply
        // senders — the worker thread itself is shielded one level down
        // (coordinator::pool), this is where replies are rescued
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            router.execute_group_with_controls(&reqs, route, &controls)
        }));
        match caught {
            Ok(responses) => {
                for (p, resp) in live.iter().zip(responses) {
                    metrics.latency.record(p.enqueued.elapsed());
                    if !resp.ok {
                        metrics
                            .errors
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if resp.error_kind
                            == Some(crate::coordinator::request::ErrorKind::Timeout)
                        {
                            metrics
                                .timeouts
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                    deliver(p, resp);
                    metrics.dec_inflight();
                }
            }
            Err(payload) => {
                let msg = format!("executor panicked: {}", panic_message(&*payload));
                for p in &live {
                    metrics.panics.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    metrics
                        .errors
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    metrics.latency.record(p.enqueued.elapsed());
                    deliver(p, Response::panicked(p.request.id, msg.clone()));
                    metrics.dec_inflight();
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Backend, ErrorKind, RequestBody};
    use crate::core::problem::SdpProblem;

    fn native_request(id: i64) -> Request {
        Request {
            id,
            body: RequestBody::Sdp(SdpProblem::fibonacci(16)),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        }
    }

    /// Same-shape request in a *different* batching bucket than
    /// [`native_request`] (n = 32 vs 16 → distinct `GroupKey::Sdp`).
    fn other_bucket_request(id: i64) -> Request {
        Request {
            id,
            body: RequestBody::Sdp(SdpProblem::fibonacci(32)),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
            stream: false,
        }
    }

    fn harness() -> (Batcher, Arc<Metrics>) {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let b = Batcher::start(router, pool, metrics.clone(), Policy::default());
        (b, metrics)
    }

    /// The memory admission gate: an oversized request is refused with a
    /// typed, id-correlated `too_large` reply before anything is claimed
    /// (no in-flight slot, no table allocation), and a request under the
    /// bound still solves through the same batcher.
    #[test]
    fn oversized_request_gets_typed_too_large() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        // fibonacci(16) estimates 16 × 8 = 128 B — set the bound below it
        let batcher =
            Batcher::start_with_limit(router, pool, metrics.clone(), Policy::default(), 64);
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(native_request(7), tx);
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(!resp.ok);
        assert_eq!(resp.id, 7);
        assert_eq!(resp.error_kind, Some(ErrorKind::TooLarge));
        assert_eq!(metrics.rejected_too_large.load(Ordering::Relaxed), 1);
        assert_eq!(
            metrics.inflight.load(Ordering::Relaxed),
            0,
            "a refused request must not hold an in-flight slot"
        );
        // fibonacci(4) estimates 32 B — admitted and solved
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(
            Request {
                id: 8,
                body: RequestBody::Sdp(SdpProblem::fibonacci(4)),
                backend: Backend::Native,
                full: false,
                want_solution: false,
                deadline_ms: None,
                stream: false,
            },
            tx,
        );
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(metrics.rejected_too_large.load(Ordering::Relaxed), 1);
    }

    /// A request whose budget is already exhausted at admission is
    /// answered with a typed `timeout` — never solved — and releases its
    /// in-flight slot.
    #[test]
    fn expired_deadline_request_sheds_with_typed_timeout() {
        let (batcher, metrics) = harness();
        let mut req = native_request(9);
        req.deadline_ms = Some(0);
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(req, tx);
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(!resp.ok);
        assert_eq!(resp.id, 9);
        assert_eq!(resp.error_kind, Some(ErrorKind::Timeout));
        assert_eq!(metrics.timeouts.load(Ordering::Relaxed), 1);
        let t0 = Instant::now();
        while metrics.inflight.load(Ordering::Relaxed) != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "slot never released");
            std::thread::yield_now();
        }
    }

    /// A generous budget changes nothing: the deadline-carrying path
    /// produces the same answer as the unbounded one.
    #[test]
    fn generous_deadline_request_solves_normally() {
        let (batcher, metrics) = harness();
        let mut req = native_request(10);
        req.deadline_ms = Some(600_000);
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(req, tx);
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.value, 987);
        assert_eq!(metrics.timeouts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn single_request_flushes_after_deadline() {
        let (batcher, _m) = harness();
        let (tx, rx) = mpsc::channel();
        batcher.submit(Pending {
            request: native_request(1),
            route: Route::Native,
            enqueued: Instant::now(),
            deadline: None,
            reply: tx.into(),
        });
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok);
        assert_eq!(resp.value, 987);
    }

    #[test]
    fn many_requests_all_answered() {
        let (batcher, metrics) = harness();
        let mut receivers = Vec::new();
        for i in 0..50 {
            let (tx, rx) = mpsc::channel();
            batcher.submit(Pending {
                request: native_request(i),
                route: Route::Native,
                enqueued: Instant::now(),
                deadline: None,
                reply: tx.into(),
            });
            receivers.push((i, rx));
        }
        for (i, rx) in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.id, i);
            assert!(resp.ok);
        }
        assert_eq!(metrics.latency.count(), 50);
    }

    #[test]
    fn full_group_flushes_by_size_not_deadline() {
        // 4 same-bucket Xla-routed requests with an effectively-infinite
        // deadline must still flush once max_batch is reached.  With no
        // engine the execution falls back per-request and errors — what
        // matters here is that the flush happens promptly at all.
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool,
            metrics.clone(),
            Policy {
                max_batch: 4,
                max_wait: Duration::from_secs(60), // only size can trigger
            },
        );
        let mut receivers = Vec::new();
        for i in 0..4 {
            let (tx, rx) = mpsc::channel();
            batcher.submit(Pending {
                request: native_request(i), // same (n, k, op) → same key
                route: Route::Xla,
                enqueued: Instant::now(),
                deadline: None,
                reply: tx.into(),
            });
            receivers.push(rx);
        }
        for rx in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert!(!resp.ok); // engine-less Xla execution is a typed error
        }
        assert_eq!(metrics.mean_batch_size(), 4.0);
    }

    #[test]
    fn native_singles_bypass_batching_window() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool,
            metrics,
            Policy {
                max_batch: 8,
                max_wait: Duration::from_secs(60),
            },
        );
        let (tx, rx) = mpsc::channel();
        batcher.submit(Pending {
            request: native_request(1),
            route: Route::Native,
            enqueued: Instant::now(),
            deadline: None,
            reply: tx.into(),
        });
        // answered well before the 60 s window
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok);
    }

    /// Regression for the deadline-starvation bug: the seed flushed
    /// expired groups only from the `recv_timeout` *timeout* arm, with a
    /// 50 µs floor on the timeout — so traffic to key A arriving faster
    /// than every 50 µs kept the loop in the `Ok` arm forever and a lone
    /// pending on key B waited until the traffic stopped.  The deadline
    /// heap flushes B on time regardless of how busy the channel is.
    #[test]
    fn cross_key_traffic_does_not_starve_other_groups() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let max_wait = Duration::from_millis(100);
        let batcher = Batcher::start(
            router,
            pool,
            metrics,
            Policy {
                max_batch: 4,
                max_wait,
            },
        );
        // lone pending on key B (n = 32 bucket)
        let (tx_b, rx_b) = mpsc::channel();
        let started = Instant::now();
        batcher.submit(Pending {
            request: other_bucket_request(1000),
            route: Route::Xla,
            enqueued: started,
            deadline: None,
            reply: tx_b.into(),
        });
        std::thread::scope(|s| {
            // key-A producer: one request every ~20 µs (well under the
            // seed's 50 µs receive-timeout floor) for well past
            // 2× max_wait; A keeps flushing by size, never by deadline.
            // The pacing loop yields rather than pure-spins so the
            // batcher thread is never starved of a core on small CI
            // runners — the 2× bound leaves ~max_wait of jitter margin.
            s.spawn(|| {
                let gap = Duration::from_micros(20);
                let mut i = 0i64;
                while started.elapsed() < Duration::from_millis(250) {
                    let (tx, _rx) = mpsc::channel::<Response>(); // A replies discarded
                    batcher.submit(Pending {
                        request: native_request(i),
                        route: Route::Xla,
                        enqueued: Instant::now(),
                        deadline: None,
                        reply: tx.into(),
                    });
                    i += 1;
                    let next = started.elapsed() + gap;
                    while started.elapsed() < next {
                        std::thread::yield_now();
                    }
                }
            });
            let resp = rx_b
                .recv_timeout(Duration::from_secs(5))
                .expect("key B must be answered at all");
            let waited = started.elapsed();
            assert!(!resp.ok); // engine-less Xla → typed error; timing is the point
            assert!(
                waited <= 2 * max_wait,
                "lone pending starved behind cross-key traffic: waited {waited:?} \
                 with max_wait {max_wait:?}"
            );
        });
    }

    /// The admission gate: with the single worker parked and `capacity`
    /// requests admitted (in flight), the next `submit_request` must
    /// answer `overloaded` immediately and tick the shed counter — even
    /// though the shed request never reaches the pool queue.
    #[test]
    fn admission_gate_sheds_when_saturated() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::with_capacity(1, 2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool.clone(),
            metrics.clone(),
            Policy::default(),
        );
        // park the worker so admitted requests cannot complete
        let (release_tx, release_rx) = mpsc::channel::<()>();
        pool.submit(move || {
            let _ = release_rx.recv();
        });
        let t0 = Instant::now();
        while pool.backlog() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5));
            std::thread::yield_now();
        }
        // fill the in-flight budget (capacity = 2) through the gate
        let mut admitted = Vec::new();
        for i in 0..2 {
            let (tx, rx) = mpsc::channel();
            batcher.submit_request(native_request(i), tx);
            admitted.push(rx);
        }
        assert_eq!(metrics.inflight.load(Ordering::Relaxed), 2);

        let (tx, rx) = mpsc::channel();
        batcher.submit_request(native_request(42), tx);
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(!resp.ok);
        assert!(resp.overloaded, "shed reply must be typed");
        assert_eq!(resp.id, 42, "shed reply must keep the request id");
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);

        // release the plug: the admitted requests complete and the gate
        // re-opens for new traffic
        release_tx.send(()).unwrap();
        for rx in admitted {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(resp.ok, "{:?}", resp.error);
        }
        let t0 = Instant::now();
        while metrics.inflight.load(Ordering::Relaxed) != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5));
            std::thread::yield_now();
        }
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(native_request(43), tx);
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);
    }

    /// `shutdown` drains pending groups (their replies arrive) and joins
    /// the batcher thread; calling it twice is fine.
    #[test]
    fn shutdown_drains_pending_groups() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool.clone(),
            metrics,
            Policy {
                max_batch: 64,
                max_wait: Duration::from_secs(60), // would park without drain
            },
        );
        let (tx, rx) = mpsc::channel();
        batcher.submit(Pending {
            request: native_request(5),
            route: Route::Xla, // groupable key: sits in the pending map
            enqueued: Instant::now(),
            deadline: None,
            reply: tx.into(),
        });
        std::thread::sleep(Duration::from_millis(20));
        batcher.shutdown();
        pool.shutdown(); // run the drained flush job
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(!resp.ok); // engine-less Xla → typed error, but *answered*
        batcher.shutdown(); // idempotent
    }

    /// A unary request over a [`ReplySink::Line`] sink is delivered as
    /// the plain reply shape — byte-identical to [`Response::encode`],
    /// with no `frame` marker — so line-oriented writers and the legacy
    /// decoded-response path stay wire-compatible.
    #[test]
    fn line_sink_unary_reply_is_plain_shape() {
        let (batcher, _m) = harness();
        let (tx, rx) = mpsc::channel::<String>();
        batcher.submit_request(native_request(3), tx);
        let line = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(!line.contains("\"frame\""), "unary reply must be frame-less: {line}");
        let resp = Response::decode(&line).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.id, 3);
        assert_eq!(resp.value, 987);
        assert_eq!(line, resp.encode(), "line must round-trip byte-identically");
    }

    /// The streaming contract end-to-end through the batcher: a
    /// `stream: true` solve over a line sink yields ≥ 1 monotone
    /// `progress` frame, the solution as `solution` chunks whose
    /// concatenation parses back to the payload, and a terminal `result`
    /// frame with the inline solution elided.
    #[test]
    fn streamed_request_frames_over_line_sink() {
        use crate::core::problem::{AlignProblem, AlignScoring, AlignVariant};
        let (batcher, _m) = harness();
        // 64×64 LCS → 127 wavefronts: plenty of cancellation poll sites
        let a: Vec<i64> = (0..64).map(|i| (i % 7) as i64).collect();
        let b: Vec<i64> = (0..64).map(|i| (i % 5) as i64).collect();
        let req = Request {
            id: 21,
            body: RequestBody::Align(
                AlignProblem::new(a, b, AlignVariant::Lcs, AlignScoring::default()).unwrap(),
            ),
            backend: Backend::Native,
            full: false,
            want_solution: true,
            deadline_ms: None,
            stream: true,
        };
        let (tx, rx) = mpsc::channel::<String>();
        batcher.submit_request(req, tx);
        let mut progress_frames = 0u64;
        let mut last_supersteps = 0u64;
        let mut next_seq = 0u64;
        let mut chunks = String::new();
        let mut saw_last_chunk = false;
        let mut result = None;
        while result.is_none() {
            let line = rx.recv_timeout(Duration::from_secs(5)).expect("stream frame");
            match Frame::decode(&line).unwrap() {
                Frame::Progress { id, supersteps, .. } => {
                    assert_eq!(id, 21);
                    assert!(
                        supersteps >= last_supersteps,
                        "progress must be monotone: {supersteps} < {last_supersteps}"
                    );
                    last_supersteps = supersteps;
                    progress_frames += 1;
                }
                Frame::SolutionChunk { id, seq, last, chunk } => {
                    assert_eq!(id, 21);
                    assert_eq!(seq, next_seq, "chunk seq must be dense from 0");
                    assert!(!saw_last_chunk, "no chunks after `last`");
                    next_seq += 1;
                    saw_last_chunk = last;
                    chunks.push_str(&chunk);
                }
                Frame::Result(r) => result = Some(r),
            }
        }
        let resp = result.unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.id, 21);
        assert!(
            resp.solution.is_none(),
            "streamed result must elide the inline solution"
        );
        assert!(progress_frames >= 1, "expected at least one progress frame");
        assert!(saw_last_chunk, "solution chunks must terminate with `last`");
        let sol = crate::util::json::Json::parse(&chunks).expect("chunks parse");
        assert_eq!(sol.i64_field("score").unwrap(), resp.value);
        // nothing after the terminal frame
        assert!(rx.recv_timeout(Duration::from_millis(100)).is_err());
    }

    /// A streamed request refused at admission still terminates its
    /// stream: the typed error arrives as a single `result` frame.
    #[test]
    fn streamed_refusal_terminates_with_result_frame() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start_with_limit(router, pool, metrics, Policy::default(), 64);
        let mut req = native_request(9); // fibonacci(16): 128 B > 64 B bound
        req.stream = true;
        let (tx, rx) = mpsc::channel::<String>();
        batcher.submit_request(req, tx);
        let line = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        match Frame::decode(&line).unwrap() {
            Frame::Result(resp) => {
                assert!(!resp.ok);
                assert_eq!(resp.id, 9);
                assert_eq!(resp.error_kind, Some(ErrorKind::TooLarge));
            }
            other => panic!("want a terminal result frame, got {other:?}"),
        }
    }

    /// Every wire-kind family rides its own shard; one request per
    /// family must be answered correctly through all five threads.
    #[test]
    fn all_kind_families_answered_across_shards() {
        use crate::core::problem::{AlignProblem, CykProblem, McmProblem, ViterbiProblem};
        use crate::core::schedule::McmVariant;
        let (batcher, _m) = harness();
        let bodies = vec![
            RequestBody::Sdp(SdpProblem::fibonacci(16)),
            RequestBody::Mcm {
                problem: McmProblem::new(vec![30, 35, 15, 5, 10, 20, 25]).unwrap(),
                variant: McmVariant::Corrected,
            },
            RequestBody::Align(AlignProblem::lcs(vec![1, 2, 3], vec![2, 3]).unwrap()),
            RequestBody::Viterbi(
                ViterbiProblem::new(
                    2,
                    1,
                    vec![0.0; 2],
                    vec![0.0; 4],
                    vec![0.0; 2],
                    vec![0, 0, 0],
                )
                .unwrap(),
            ),
            RequestBody::Cyk(CykProblem::balanced_example(3)),
        ];
        // the five bodies above cover all five shards exactly once
        let shards: std::collections::HashSet<usize> = bodies.iter().map(shard_of).collect();
        assert_eq!(shards.len(), NUM_SHARDS);
        let mut receivers = Vec::new();
        for (i, body) in bodies.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            batcher.submit_request(
                Request {
                    id: i as i64,
                    body,
                    backend: Backend::Native,
                    full: false,
                    want_solution: false,
                    deadline_ms: None,
                    stream: false,
                },
                tx,
            );
            receivers.push((i as i64, rx));
        }
        for (id, rx) in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.id, id);
            assert!(resp.ok, "family {id} failed: {:?}", resp.error);
        }
    }
}
