//! Dynamic batching: hold compatible requests for up to `max_wait` (or
//! until `max_batch` accumulate) so one PJRT dispatch serves many — the
//! same policy a serving router applies to model invocations.
//!
//! Schedule compilation is *not* part of the dispatch cost the batcher
//! amortizes: every execution path it flushes into (native MCM solve,
//! XLA schedule-executor dispatch) fetches its schedule from the
//! process-wide cache ([`crate::core::cache`]), so only the first request
//! per `(kind, n, variant)` in the process lifetime compiles one, and the
//! server warmup pre-warms the cache for every registered bucket.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::request::{Request, Response};
use crate::coordinator::router::{group_key, GroupKey, Route, Router};

/// A request waiting for dispatch, with its reply channel.
pub struct Pending {
    pub request: Request,
    pub route: Route,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct Policy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for Policy {
    fn default() -> Self {
        Policy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// The batcher thread: owns the pending map, flushes groups to the pool.
pub struct Batcher {
    tx: mpsc::Sender<Pending>,
    router: Arc<Router>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn start(
        router: Arc<Router>,
        pool: Arc<WorkerPool>,
        metrics: Arc<Metrics>,
        policy: Policy,
    ) -> Batcher {
        let (tx, rx) = mpsc::channel::<Pending>();
        let handle = {
            let router = router.clone();
            std::thread::Builder::new()
                .name("pipedp-batcher".into())
                .spawn(move || run(rx, router, pool, metrics, policy))
                .expect("spawn batcher")
        };
        Batcher {
            tx,
            router,
            handle: Some(handle),
        }
    }

    /// Hand a pre-routed request to the batcher.
    pub fn submit(&self, pending: Pending) {
        // a send failure means the batcher thread exited: the reply channel
        // is dropped and the connection sees a disconnect
        let _ = self.tx.send(pending);
    }

    /// Route + enqueue; routing failures answer immediately.
    pub fn submit_request(
        &self,
        request: Request,
        reply: mpsc::Sender<crate::coordinator::request::Response>,
    ) {
        match self.router.route(&request) {
            Ok(route) => self.submit(Pending {
                request,
                route,
                enqueued: Instant::now(),
                reply,
            }),
            Err(e) => {
                let _ = reply.send(crate::coordinator::request::Response::err(
                    request.id,
                    e.to_string(),
                ));
            }
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // closing tx ends the loop after a final flush
        let (dead_tx, _) = mpsc::channel();
        self.tx = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn run(
    rx: mpsc::Receiver<Pending>,
    router: Arc<Router>,
    pool: Arc<WorkerPool>,
    metrics: Arc<Metrics>,
    policy: Policy,
) {
    let mut groups: HashMap<GroupKey, Vec<Pending>> = HashMap::new();
    loop {
        // wait bounded by the oldest pending deadline
        let timeout = groups
            .values()
            .flat_map(|g| g.iter().map(|p| p.enqueued))
            .min()
            .map(|oldest| {
                policy
                    .max_wait
                    .saturating_sub(oldest.elapsed())
                    .max(Duration::from_micros(50))
            })
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(p) => {
                let key = group_key(&p.request, p.route);
                // Single keys can never grow — dispatch immediately rather
                // than paying the batching window for nothing.
                if matches!(key, GroupKey::Single(_)) {
                    flush(vec![p], &router, &pool, &metrics);
                    continue;
                }
                let group = groups.entry(key.clone()).or_default();
                group.push(p);
                if group.len() >= policy.max_batch {
                    let batch = groups.remove(&key).unwrap();
                    flush(batch, &router, &pool, &metrics);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let expired: Vec<GroupKey> = groups
                    .iter()
                    .filter(|(_, g)| {
                        g.iter().any(|p| p.enqueued.elapsed() >= policy.max_wait)
                    })
                    .map(|(k, _)| k.clone())
                    .collect();
                for key in expired {
                    let batch = groups.remove(&key).unwrap();
                    flush(batch, &router, &pool, &metrics);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for (_, batch) in groups.drain() {
                    flush(batch, &router, &pool, &metrics);
                }
                return;
            }
        }
    }
}

fn flush(batch: Vec<Pending>, router: &Arc<Router>, pool: &Arc<WorkerPool>, metrics: &Arc<Metrics>) {
    if batch.is_empty() {
        return;
    }
    let router = router.clone();
    let metrics = metrics.clone();
    metrics.record_batch(batch.len());
    pool.submit(move || {
        for p in &batch {
            metrics.queue_wait.record(p.enqueued.elapsed());
        }
        let route = batch[0].route;
        let reqs: Vec<Request> = batch.iter().map(|p| p.request.clone()).collect();
        let started = Instant::now();
        let responses = router.execute_group(&reqs, route);
        let elapsed = started.elapsed();
        for (p, resp) in batch.iter().zip(responses) {
            metrics.latency.record(p.enqueued.elapsed());
            if !resp.ok {
                metrics
                    .errors
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            let _ = p.reply.send(resp);
        }
        let _ = elapsed;
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Backend, RequestBody};
    use crate::core::problem::SdpProblem;

    fn native_request(id: i64) -> Request {
        Request {
            id,
            body: RequestBody::Sdp(SdpProblem::fibonacci(16)),
            backend: Backend::Native,
            full: false,
        }
    }

    fn harness() -> (Batcher, Arc<Metrics>) {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let b = Batcher::start(router, pool, metrics.clone(), Policy::default());
        (b, metrics)
    }

    #[test]
    fn single_request_flushes_after_deadline() {
        let (batcher, _m) = harness();
        let (tx, rx) = mpsc::channel();
        batcher.submit(Pending {
            request: native_request(1),
            route: Route::Native,
            enqueued: Instant::now(),
            reply: tx,
        });
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok);
        assert_eq!(resp.value, 987);
    }

    #[test]
    fn many_requests_all_answered() {
        let (batcher, metrics) = harness();
        let mut receivers = Vec::new();
        for i in 0..50 {
            let (tx, rx) = mpsc::channel();
            batcher.submit(Pending {
                request: native_request(i),
                route: Route::Native,
                enqueued: Instant::now(),
                reply: tx,
            });
            receivers.push((i, rx));
        }
        for (i, rx) in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.id, i);
            assert!(resp.ok);
        }
        assert_eq!(metrics.latency.count(), 50);
    }

    #[test]
    fn full_group_flushes_by_size_not_deadline() {
        // 4 same-bucket Xla-routed requests with an effectively-infinite
        // deadline must still flush once max_batch is reached.  With no
        // engine the execution falls back per-request and errors — what
        // matters here is that the flush happens promptly at all.
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool,
            metrics.clone(),
            Policy {
                max_batch: 4,
                max_wait: Duration::from_secs(60), // only size can trigger
            },
        );
        let mut receivers = Vec::new();
        for i in 0..4 {
            let (tx, rx) = mpsc::channel();
            batcher.submit(Pending {
                request: native_request(i), // same (n, k, op) → same key
                route: Route::Xla,
                enqueued: Instant::now(),
                reply: tx,
            });
            receivers.push(rx);
        }
        for rx in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert!(!resp.ok); // engine-less Xla execution is a typed error
        }
        assert_eq!(metrics.mean_batch_size(), 4.0);
    }

    #[test]
    fn native_singles_bypass_batching_window() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool,
            metrics,
            Policy {
                max_batch: 8,
                max_wait: Duration::from_secs(60),
            },
        );
        let (tx, rx) = mpsc::channel();
        batcher.submit(Pending {
            request: native_request(1),
            route: Route::Native,
            enqueued: Instant::now(),
            reply: tx,
        });
        // answered well before the 60 s window
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok);
    }
}
