//! Dynamic batching: hold compatible requests for up to `max_wait` (or
//! until `max_batch` accumulate) so one PJRT dispatch serves many — the
//! same policy a serving router applies to model invocations.
//!
//! Deadlines are tracked in a min-heap keyed by `enqueued + max_wait`
//! (one entry per group creation, lazily invalidated), and expired groups
//! are flushed on **every** loop iteration — not only when the request
//! channel goes quiet.  The seed flushed deadlines only from the
//! `recv_timeout` timeout arm, so a steady trickle of traffic to *other*
//! group keys could starve a partial batch far past its deadline.
//!
//! Admission is gated before anything enters the batcher: when the worker
//! pool's bounded queue is full, `submit_request` sheds the request with a
//! typed `overloaded` reply (and a `shed` metrics tick) instead of
//! queueing it without bound (DESIGN.md §2).
//!
//! Schedule compilation is *not* part of the dispatch cost the batcher
//! amortizes: every execution path it flushes into (native MCM solve,
//! XLA schedule-executor dispatch) fetches its schedule from the
//! process-wide cache ([`crate::core::cache`]), so only the first request
//! per `(kind, n, variant)` in the process lifetime compiles one, and the
//! server warmup pre-warms the cache for every registered bucket.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::request::{Request, Response};
use crate::coordinator::router::{group_key, GroupKey, Route, Router};

/// A request waiting for dispatch, with its reply channel.
pub struct Pending {
    pub request: Request,
    pub route: Route,
    pub enqueued: Instant,
    /// Absolute deadline derived from the request's `deadline_ms` at
    /// admission; `None` = unbounded.  Entries past it are shed from the
    /// flush with a typed `timeout` reply instead of being solved, and
    /// live ones thread it into the executors' cancel tokens.
    pub deadline: Option<Instant>,
    pub reply: mpsc::Sender<Response>,
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct Policy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for Policy {
    fn default() -> Self {
        Policy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// What flows to the batcher thread: requests, or the drain signal.
enum Msg {
    Req(Box<Pending>),
    Stop,
}

/// The batcher thread: owns the pending map + deadline heap, flushes
/// groups to the pool.
pub struct Batcher {
    tx: mpsc::Sender<Msg>,
    router: Arc<Router>,
    pool: Arc<WorkerPool>,
    metrics: Arc<Metrics>,
    /// Memory admission bound (bytes of estimated solve footprint);
    /// 0 = unlimited.  Checked in [`Batcher::submit_request`] *before*
    /// the in-flight slot claim — an oversized request is refused with a
    /// typed `too_large` reply and never allocates a table.
    max_solve_bytes: usize,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Batcher {
    pub fn start(
        router: Arc<Router>,
        pool: Arc<WorkerPool>,
        metrics: Arc<Metrics>,
        policy: Policy,
    ) -> Batcher {
        Batcher::start_with_limit(router, pool, metrics, policy, 0)
    }

    /// [`Batcher::start`] with a memory admission bound (0 = unlimited).
    pub fn start_with_limit(
        router: Arc<Router>,
        pool: Arc<WorkerPool>,
        metrics: Arc<Metrics>,
        policy: Policy,
        max_solve_bytes: usize,
    ) -> Batcher {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = {
            let router = router.clone();
            let pool = pool.clone();
            let metrics = metrics.clone();
            std::thread::Builder::new()
                .name("pipedp-batcher".into())
                .spawn(move || run(rx, router, pool, metrics, policy))
                .expect("spawn batcher")
        };
        Batcher {
            tx,
            router,
            pool,
            metrics,
            max_solve_bytes,
            handle: Mutex::new(Some(handle)),
        }
    }

    /// Hand a pre-routed request to the batcher, counting it in flight
    /// (the slot is released when its reply is sent), so direct
    /// submissions and gate-admitted ones share one accounting and the
    /// admission bound stays honest under mixed use.  `false` means the
    /// batcher thread is gone and the pending (with its reply sender)
    /// was dropped — the connection sees a disconnect for that request.
    pub fn submit(&self, pending: Pending) -> bool {
        self.metrics.inflight.fetch_add(1, Ordering::Relaxed);
        self.enqueue(pending)
    }

    /// Send a pending whose in-flight slot is already claimed; on a dead
    /// batcher thread the slot is released here.
    fn enqueue(&self, pending: Pending) -> bool {
        let ok = self.tx.send(Msg::Req(Box::new(pending))).is_ok();
        if !ok {
            self.metrics.dec_inflight();
        }
        ok
    }

    /// Route + enqueue; routing failures answer immediately, and a
    /// saturated coordinator sheds with a typed `overloaded` reply.
    ///
    /// The admission gate bounds *total requests in flight* (batcher
    /// channel + pending groups + worker queue + executing) by the worker
    /// queue capacity — gating on the pool backlog alone would let a
    /// fast-arriving burst hide in the batcher's channel and bypass the
    /// bound.  The backlog check stays as a second trigger for work that
    /// enters the pool without passing this gate.
    pub fn submit_request(&self, request: Request, reply: mpsc::Sender<Response>) {
        // memory admission: a statically-oversized request is refused
        // before claiming anything — load cannot make it admissible
        let est = request.body.estimated_solve_bytes(request.want_solution);
        if self.max_solve_bytes > 0 && est > self.max_solve_bytes as u64 {
            self.metrics.rejected_too_large.fetch_add(1, Ordering::Relaxed);
            let _ = reply.send(Response::too_large(
                request.id,
                format!(
                    "estimated solve footprint {est} B exceeds the admission \
                     bound {} B",
                    self.max_solve_bytes
                ),
            ));
            return;
        }
        let cap = self.pool.capacity();
        // reserve-then-check: the fetch_add atomically claims an in-flight
        // slot, so concurrent connection threads cannot jointly race a
        // load-then-increment past the bound; a failed claim is undone
        let saturated = if self.pool.backlog() >= cap {
            true
        } else if self.metrics.inflight.fetch_add(1, Ordering::Relaxed) >= cap as u64 {
            self.metrics.dec_inflight();
            true
        } else {
            false
        };
        if saturated {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            let _ = reply.send(Response::overloaded(request.id));
            return;
        }
        match self.router.route(&request) {
            // the claimed slot is released when the reply is sent (flush) —
            // see Metrics::dec_inflight for the saturating contract
            Ok(route) => {
                let request_id = request.id;
                let reply2 = reply.clone();
                let now = Instant::now();
                // the budget clock starts at admission: deadline_ms is
                // relative to arrival, converted once to an absolute
                // Instant that queue, shed, and executors all compare to
                // checked_add: an astronomically large budget saturates to
                // "unbounded" instead of panicking on Instant overflow
                let deadline = request
                    .deadline_ms
                    .and_then(|ms| now.checked_add(Duration::from_millis(ms)));
                // enqueue, not submit: the gate's fetch_add above already
                // claimed this request's slot, and enqueue releases it if
                // the batcher thread is gone (else the gauge would ratchet
                // to cap and shed forever)
                let accepted = self.enqueue(Pending {
                    request,
                    route,
                    enqueued: now,
                    deadline,
                    reply,
                });
                if !accepted {
                    let _ = reply2
                        .send(Response::err(request_id, "batcher unavailable".to_string()));
                }
            }
            Err(e) => {
                let _ = reply.send(Response::err(request.id, e.to_string()));
                self.metrics.dec_inflight(); // answered now: not in flight
            }
        }
    }

    /// Drain every pending group into the pool and join the batcher
    /// thread.  Idempotent; `Drop` calls it too.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Idle wait when no group holds a deadline.
const IDLE_WAIT: Duration = Duration::from_millis(50);

fn run(
    rx: mpsc::Receiver<Msg>,
    router: Arc<Router>,
    pool: Arc<WorkerPool>,
    metrics: Arc<Metrics>,
    policy: Policy,
) {
    let mut groups: HashMap<GroupKey, Vec<Pending>> = HashMap::new();
    // Min-heap of (deadline, key).  One entry is pushed per group
    // *creation*; entries whose group was since flushed (a re-created
    // group pushes its own fresh entry) are dropped lazily on surfacing.
    let mut deadlines: BinaryHeap<Reverse<(Instant, GroupKey)>> = BinaryHeap::new();
    loop {
        // flush everything past its deadline on every iteration — a busy
        // receive stream must never postpone another group's deadline
        flush_expired(
            &mut groups,
            &mut deadlines,
            &router,
            &pool,
            &metrics,
            policy.max_wait,
        );
        // after flush_expired the heap top (if any) is live and in the
        // future, so it is exactly the next wake-up time
        let timeout = match deadlines.peek() {
            Some(Reverse((at, _))) => at
                .saturating_duration_since(Instant::now())
                .max(Duration::from_micros(50)),
            None => IDLE_WAIT,
        };
        match rx.recv_timeout(timeout) {
            Ok(Msg::Req(p)) => {
                let p = *p;
                let key = group_key(&p.request, p.route);
                // Single keys can never grow — dispatch immediately rather
                // than paying the batching window for nothing.
                if matches!(key, GroupKey::Single(_)) {
                    flush(vec![p], &router, &pool, &metrics);
                    continue;
                }
                let group = groups.entry(key.clone()).or_default();
                if group.is_empty() {
                    // first pending defines the group deadline (arrivals
                    // are appended, so index 0 stays the oldest)
                    deadlines.push(Reverse((p.enqueued + policy.max_wait, key.clone())));
                }
                group.push(p);
                if group.len() >= policy.max_batch {
                    let batch = groups.remove(&key).unwrap();
                    flush(batch, &router, &pool, &metrics);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Ok(Msg::Stop) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                for (_, batch) in groups.drain() {
                    flush(batch, &router, &pool, &metrics);
                }
                return;
            }
        }
    }
}

/// Pop and flush every group whose deadline has passed.  Stale heap
/// entries — the group was flushed by size, whether or not a later
/// re-creation (with its own fresh entry) exists — are discarded here,
/// so on return the heap top is a live, future deadline.
fn flush_expired(
    groups: &mut HashMap<GroupKey, Vec<Pending>>,
    deadlines: &mut BinaryHeap<Reverse<(Instant, GroupKey)>>,
    router: &Arc<Router>,
    pool: &Arc<WorkerPool>,
    metrics: &Arc<Metrics>,
    max_wait: Duration,
) {
    let now = Instant::now();
    loop {
        let (at, key) = match deadlines.peek() {
            Some(Reverse((at, key))) => (*at, key.clone()),
            None => return,
        };
        let live = match groups.get(&key) {
            // group already flushed: drop the stale entry
            None => {
                deadlines.pop();
                continue;
            }
            Some(g) => g[0].enqueued + max_wait,
        };
        if live > at {
            // the key was flushed by size and re-created since this entry
            // was pushed; the re-creation pushed its own (later) entry,
            // so this stale one is simply dropped
            deadlines.pop();
            continue;
        }
        if at > now {
            return; // heap top is live and future — nothing else expired
        }
        deadlines.pop();
        let batch = groups.remove(&key).unwrap();
        flush(batch, router, pool, metrics);
    }
}

/// Best-effort text of a caught panic payload (`&str` and `String` cover
/// every `panic!` in this crate; anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

fn flush(batch: Vec<Pending>, router: &Arc<Router>, pool: &Arc<WorkerPool>, metrics: &Arc<Metrics>) {
    if batch.is_empty() {
        return;
    }
    let router = router.clone();
    let metrics = metrics.clone();
    metrics.record_batch(batch.len());
    pool.submit(move || {
        for p in &batch {
            metrics.queue_wait.record(p.enqueued.elapsed());
        }
        // shed entries whose deadline passed while queued: a typed
        // `timeout` reply now is strictly better than a solve whose
        // answer nobody is waiting for (and whose table still costs RAM)
        let now = Instant::now();
        let (expired, live): (Vec<Pending>, Vec<Pending>) = batch
            .into_iter()
            .partition(|p| p.deadline.is_some_and(|d| d <= now));
        for p in expired {
            metrics.timeouts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            metrics
                .errors
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            metrics.latency.record(p.enqueued.elapsed());
            let _ = p.reply.send(Response::timeout(p.request.id));
            metrics.dec_inflight();
        }
        if live.is_empty() {
            return;
        }
        let route = live[0].route;
        let reqs: Vec<Request> = live.iter().map(|p| p.request.clone()).collect();
        let deadlines: Vec<Option<Instant>> = live.iter().map(|p| p.deadline).collect();
        // isolation boundary: an executor panic (a bug, or an injected
        // fault) must answer every request in the group with a typed,
        // id-correlated `panicked` reply instead of dropping the reply
        // senders — the worker thread itself is shielded one level down
        // (coordinator::pool), this is where replies are rescued
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            router.execute_group_with_deadlines(&reqs, route, &deadlines)
        }));
        match caught {
            Ok(responses) => {
                for (p, resp) in live.iter().zip(responses) {
                    metrics.latency.record(p.enqueued.elapsed());
                    if !resp.ok {
                        metrics
                            .errors
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if resp.error_kind
                            == Some(crate::coordinator::request::ErrorKind::Timeout)
                        {
                            metrics
                                .timeouts
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                    let _ = p.reply.send(resp);
                    metrics.dec_inflight();
                }
            }
            Err(payload) => {
                let msg = format!("executor panicked: {}", panic_message(&*payload));
                for p in &live {
                    metrics.panics.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    metrics
                        .errors
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    metrics.latency.record(p.enqueued.elapsed());
                    let _ = p.reply.send(Response::panicked(p.request.id, msg.clone()));
                    metrics.dec_inflight();
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Backend, ErrorKind, RequestBody};
    use crate::core::problem::SdpProblem;

    fn native_request(id: i64) -> Request {
        Request {
            id,
            body: RequestBody::Sdp(SdpProblem::fibonacci(16)),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
        }
    }

    /// Same-shape request in a *different* batching bucket than
    /// [`native_request`] (n = 32 vs 16 → distinct `GroupKey::Sdp`).
    fn other_bucket_request(id: i64) -> Request {
        Request {
            id,
            body: RequestBody::Sdp(SdpProblem::fibonacci(32)),
            backend: Backend::Native,
            full: false,
            want_solution: false,
            deadline_ms: None,
        }
    }

    fn harness() -> (Batcher, Arc<Metrics>) {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let b = Batcher::start(router, pool, metrics.clone(), Policy::default());
        (b, metrics)
    }

    /// The memory admission gate: an oversized request is refused with a
    /// typed, id-correlated `too_large` reply before anything is claimed
    /// (no in-flight slot, no table allocation), and a request under the
    /// bound still solves through the same batcher.
    #[test]
    fn oversized_request_gets_typed_too_large() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        // fibonacci(16) estimates 16 × 8 = 128 B — set the bound below it
        let batcher =
            Batcher::start_with_limit(router, pool, metrics.clone(), Policy::default(), 64);
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(native_request(7), tx);
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(!resp.ok);
        assert_eq!(resp.id, 7);
        assert_eq!(resp.error_kind, Some(ErrorKind::TooLarge));
        assert_eq!(metrics.rejected_too_large.load(Ordering::Relaxed), 1);
        assert_eq!(
            metrics.inflight.load(Ordering::Relaxed),
            0,
            "a refused request must not hold an in-flight slot"
        );
        // fibonacci(4) estimates 32 B — admitted and solved
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(
            Request {
                id: 8,
                body: RequestBody::Sdp(SdpProblem::fibonacci(4)),
                backend: Backend::Native,
                full: false,
                want_solution: false,
                deadline_ms: None,
            },
            tx,
        );
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(metrics.rejected_too_large.load(Ordering::Relaxed), 1);
    }

    /// A request whose budget is already exhausted at admission is
    /// answered with a typed `timeout` — never solved — and releases its
    /// in-flight slot.
    #[test]
    fn expired_deadline_request_sheds_with_typed_timeout() {
        let (batcher, metrics) = harness();
        let mut req = native_request(9);
        req.deadline_ms = Some(0);
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(req, tx);
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(!resp.ok);
        assert_eq!(resp.id, 9);
        assert_eq!(resp.error_kind, Some(ErrorKind::Timeout));
        assert_eq!(metrics.timeouts.load(Ordering::Relaxed), 1);
        let t0 = Instant::now();
        while metrics.inflight.load(Ordering::Relaxed) != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "slot never released");
            std::thread::yield_now();
        }
    }

    /// A generous budget changes nothing: the deadline-carrying path
    /// produces the same answer as the unbounded one.
    #[test]
    fn generous_deadline_request_solves_normally() {
        let (batcher, metrics) = harness();
        let mut req = native_request(10);
        req.deadline_ms = Some(600_000);
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(req, tx);
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.value, 987);
        assert_eq!(metrics.timeouts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn single_request_flushes_after_deadline() {
        let (batcher, _m) = harness();
        let (tx, rx) = mpsc::channel();
        batcher.submit(Pending {
            request: native_request(1),
            route: Route::Native,
            enqueued: Instant::now(),
            deadline: None,
            reply: tx,
        });
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok);
        assert_eq!(resp.value, 987);
    }

    #[test]
    fn many_requests_all_answered() {
        let (batcher, metrics) = harness();
        let mut receivers = Vec::new();
        for i in 0..50 {
            let (tx, rx) = mpsc::channel();
            batcher.submit(Pending {
                request: native_request(i),
                route: Route::Native,
                enqueued: Instant::now(),
                deadline: None,
                reply: tx,
            });
            receivers.push((i, rx));
        }
        for (i, rx) in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.id, i);
            assert!(resp.ok);
        }
        assert_eq!(metrics.latency.count(), 50);
    }

    #[test]
    fn full_group_flushes_by_size_not_deadline() {
        // 4 same-bucket Xla-routed requests with an effectively-infinite
        // deadline must still flush once max_batch is reached.  With no
        // engine the execution falls back per-request and errors — what
        // matters here is that the flush happens promptly at all.
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool,
            metrics.clone(),
            Policy {
                max_batch: 4,
                max_wait: Duration::from_secs(60), // only size can trigger
            },
        );
        let mut receivers = Vec::new();
        for i in 0..4 {
            let (tx, rx) = mpsc::channel();
            batcher.submit(Pending {
                request: native_request(i), // same (n, k, op) → same key
                route: Route::Xla,
                enqueued: Instant::now(),
                deadline: None,
                reply: tx,
            });
            receivers.push(rx);
        }
        for rx in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert!(!resp.ok); // engine-less Xla execution is a typed error
        }
        assert_eq!(metrics.mean_batch_size(), 4.0);
    }

    #[test]
    fn native_singles_bypass_batching_window() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool,
            metrics,
            Policy {
                max_batch: 8,
                max_wait: Duration::from_secs(60),
            },
        );
        let (tx, rx) = mpsc::channel();
        batcher.submit(Pending {
            request: native_request(1),
            route: Route::Native,
            enqueued: Instant::now(),
            deadline: None,
            reply: tx,
        });
        // answered well before the 60 s window
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.ok);
    }

    /// Regression for the deadline-starvation bug: the seed flushed
    /// expired groups only from the `recv_timeout` *timeout* arm, with a
    /// 50 µs floor on the timeout — so traffic to key A arriving faster
    /// than every 50 µs kept the loop in the `Ok` arm forever and a lone
    /// pending on key B waited until the traffic stopped.  The deadline
    /// heap flushes B on time regardless of how busy the channel is.
    #[test]
    fn cross_key_traffic_does_not_starve_other_groups() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let max_wait = Duration::from_millis(100);
        let batcher = Batcher::start(
            router,
            pool,
            metrics,
            Policy {
                max_batch: 4,
                max_wait,
            },
        );
        // lone pending on key B (n = 32 bucket)
        let (tx_b, rx_b) = mpsc::channel();
        let started = Instant::now();
        batcher.submit(Pending {
            request: other_bucket_request(1000),
            route: Route::Xla,
            enqueued: started,
            deadline: None,
            reply: tx_b,
        });
        std::thread::scope(|s| {
            // key-A producer: one request every ~20 µs (well under the
            // seed's 50 µs receive-timeout floor) for well past
            // 2× max_wait; A keeps flushing by size, never by deadline.
            // The pacing loop yields rather than pure-spins so the
            // batcher thread is never starved of a core on small CI
            // runners — the 2× bound leaves ~max_wait of jitter margin.
            s.spawn(|| {
                let gap = Duration::from_micros(20);
                let mut i = 0i64;
                while started.elapsed() < Duration::from_millis(250) {
                    let (tx, _rx) = mpsc::channel(); // A replies discarded
                    batcher.submit(Pending {
                        request: native_request(i),
                        route: Route::Xla,
                        enqueued: Instant::now(),
                        deadline: None,
                        reply: tx,
                    });
                    i += 1;
                    let next = started.elapsed() + gap;
                    while started.elapsed() < next {
                        std::thread::yield_now();
                    }
                }
            });
            let resp = rx_b
                .recv_timeout(Duration::from_secs(5))
                .expect("key B must be answered at all");
            let waited = started.elapsed();
            assert!(!resp.ok); // engine-less Xla → typed error; timing is the point
            assert!(
                waited <= 2 * max_wait,
                "lone pending starved behind cross-key traffic: waited {waited:?} \
                 with max_wait {max_wait:?}"
            );
        });
    }

    /// The admission gate: with the single worker parked and `capacity`
    /// requests admitted (in flight), the next `submit_request` must
    /// answer `overloaded` immediately and tick the shed counter — even
    /// though the shed request never reaches the pool queue.
    #[test]
    fn admission_gate_sheds_when_saturated() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::with_capacity(1, 2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool.clone(),
            metrics.clone(),
            Policy::default(),
        );
        // park the worker so admitted requests cannot complete
        let (release_tx, release_rx) = mpsc::channel::<()>();
        pool.submit(move || {
            let _ = release_rx.recv();
        });
        let t0 = Instant::now();
        while pool.backlog() != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5));
            std::thread::yield_now();
        }
        // fill the in-flight budget (capacity = 2) through the gate
        let mut admitted = Vec::new();
        for i in 0..2 {
            let (tx, rx) = mpsc::channel();
            batcher.submit_request(native_request(i), tx);
            admitted.push(rx);
        }
        assert_eq!(metrics.inflight.load(Ordering::Relaxed), 2);

        let (tx, rx) = mpsc::channel();
        batcher.submit_request(native_request(42), tx);
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(!resp.ok);
        assert!(resp.overloaded, "shed reply must be typed");
        assert_eq!(resp.id, 42, "shed reply must keep the request id");
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);

        // release the plug: the admitted requests complete and the gate
        // re-opens for new traffic
        release_tx.send(()).unwrap();
        for rx in admitted {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(resp.ok, "{:?}", resp.error);
        }
        let t0 = Instant::now();
        while metrics.inflight.load(Ordering::Relaxed) != 0 {
            assert!(t0.elapsed() < Duration::from_secs(5));
            std::thread::yield_now();
        }
        let (tx, rx) = mpsc::channel();
        batcher.submit_request(native_request(43), tx);
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);
    }

    /// `shutdown` drains pending groups (their replies arrive) and joins
    /// the batcher thread; calling it twice is fine.
    #[test]
    fn shutdown_drains_pending_groups() {
        let router = Arc::new(Router::new(None));
        let pool = Arc::new(WorkerPool::new(2));
        let metrics = Arc::new(Metrics::default());
        let batcher = Batcher::start(
            router,
            pool.clone(),
            metrics,
            Policy {
                max_batch: 64,
                max_wait: Duration::from_secs(60), // would park without drain
            },
        );
        let (tx, rx) = mpsc::channel();
        batcher.submit(Pending {
            request: native_request(5),
            route: Route::Xla, // groupable key: sits in the pending map
            enqueued: Instant::now(),
            deadline: None,
            reply: tx,
        });
        std::thread::sleep(Duration::from_millis(20));
        batcher.shutdown();
        pool.shutdown(); // run the drained flush job
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(!resp.ok); // engine-less Xla → typed error, but *answered*
        batcher.shutdown(); // idempotent
    }
}
