//! Service metrics: lock-free counters and a log-bucketed latency
//! histogram with percentile queries.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Power-of-two bucketed latency histogram, 1 µs … ~17 s.
pub struct Histogram {
    /// bucket b counts samples in [2^b, 2^(b+1)) microseconds
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_micros: AtomicU64,
}

const NBUCKETS: usize = 25;

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(NBUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_micros.load(Ordering::Relaxed) / c)
    }

    /// Approximate percentile (upper bucket bound), q in [0, 1].
    ///
    /// `q = 0.0` answers with the first *occupied* bucket's bound (the
    /// smallest recorded sample's bucket), not the histogram floor: the
    /// target rank is clamped to ≥ 1 so the accumulator must actually
    /// reach a sample before answering.
    pub fn percentile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (((total as f64) * q).ceil() as u64).max(1);
        let mut acc = 0;
        for (b, bucket) in self.buckets.iter().enumerate() {
            acc += bucket.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(1 << (b + 1));
            }
        }
        Duration::from_micros(1 << NBUCKETS)
    }
}

/// Coordinator-wide metrics.
#[derive(Default)]
pub struct Metrics {
    pub latency: Histogram,
    pub queue_wait: Histogram,
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    /// Requests refused by the admission gate (typed `overloaded` reply)
    /// instead of queueing past the worker-pool bound.
    pub shed: AtomicU64,
    /// Gauge: submitted requests not yet answered (batcher channel +
    /// pending groups + worker queue + executing), counted for gated and
    /// direct submissions alike.  The admission gate sheds against it,
    /// so saturation cannot hide in any intermediate queue.  Decrements
    /// saturate at 0 ([`Metrics::dec_inflight`]).
    pub inflight: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Requests answered with a typed `timeout` reply: the deadline
    /// expired in the queue (shed before solving) or mid-solve (the
    /// executor's cancel token fired at a superstep boundary).
    pub timeouts: AtomicU64,
    /// Requests answered with a typed `panicked` reply after the
    /// coordinator isolation boundary caught an executor panic.
    pub panics: AtomicU64,
    /// Requests refused by the memory admission gate with a typed
    /// `too_large` reply before any table allocation.
    pub rejected_too_large: AtomicU64,
}

impl Metrics {
    /// Saturating in-flight decrement: shutdown-drain edge paths can
    /// answer pendings whose claims died with the batcher channel, so
    /// the gauge clamps at 0 instead of wrapping.
    pub fn dec_inflight(&self) {
        let _ = self
            .inflight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Mean requests per dispatched batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn snapshot(&self) -> Json {
        // schedule-cache health rides along in every stats response: the
        // cache is process-wide (crate::core::cache), so the snapshot is
        // the coordinator's one observability window into it — likewise
        // the adaptive executor policy's choice counters and the
        // persistent exec pool's occupancy (DESIGN.md §7).  Pool stats
        // are zero when no pooled solve has run yet: the stats path must
        // not lazily spawn the pool's workers.
        let sched = crate::core::cache::global_stats();
        let policy = crate::core::policy::stats();
        let pool = crate::runtime::exec_pool::try_global_stats();
        let cert = crate::core::certify::stats();
        Json::obj(vec![
            ("requests", Json::int(self.requests.load(Ordering::Relaxed) as i64)),
            ("errors", Json::int(self.errors.load(Ordering::Relaxed) as i64)),
            ("shed", Json::int(self.shed.load(Ordering::Relaxed) as i64)),
            ("timeouts", Json::int(self.timeouts.load(Ordering::Relaxed) as i64)),
            ("panics", Json::int(self.panics.load(Ordering::Relaxed) as i64)),
            (
                "rejected_too_large",
                Json::int(self.rejected_too_large.load(Ordering::Relaxed) as i64),
            ),
            ("inflight", Json::int(self.inflight.load(Ordering::Relaxed) as i64)),
            ("batches", Json::int(self.batches.load(Ordering::Relaxed) as i64)),
            ("mean_batch_size", Json::num(self.mean_batch_size())),
            ("latency_mean_us", Json::int(self.latency.mean().as_micros() as i64)),
            ("latency_p50_us", Json::int(self.latency.percentile(0.5).as_micros() as i64)),
            ("latency_p99_us", Json::int(self.latency.percentile(0.99).as_micros() as i64)),
            ("queue_p50_us", Json::int(self.queue_wait.percentile(0.5).as_micros() as i64)),
            ("queue_p99_us", Json::int(self.queue_wait.percentile(0.99).as_micros() as i64)),
            ("sched_cache_hits", Json::int(sched.hits as i64)),
            ("sched_cache_misses", Json::int(sched.misses as i64)),
            ("sched_cache_entries", Json::int(sched.entries as i64)),
            // the certifier gate's serve-path verdict counters
            // (DESIGN.md §10): every native solve passes the gate, so
            // `certified` grows with native traffic and `cert_rejected`
            // stays 0 unless a schedule was refuted
            ("certified", Json::int(cert.certified as i64)),
            ("cert_rejected", Json::int(cert.cert_rejected as i64)),
            ("policy_calibrated", Json::Bool(policy.calibrated)),
            ("policy_seq", Json::int(policy.seq as i64)),
            ("policy_fused", Json::int(policy.fused as i64)),
            ("policy_pooled", Json::int(policy.pooled as i64)),
            ("policy_simd", Json::int(policy.simd as i64)),
            (
                "exec_pool_threads",
                Json::int(pool.map_or(0, |p| p.threads as i64)),
            ),
            (
                "exec_pool_solves",
                Json::int(pool.map_or(0, |p| p.solves as i64)),
            ),
            (
                "exec_pool_active",
                Json::int(pool.map_or(0, |p| p.active as i64)),
            ),
            (
                "exec_pool_contended",
                Json::int(pool.map_or(0, |p| p.contended as i64)),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let h = Histogram::default();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.count(), 2);
        assert_eq!(h.mean(), Duration::from_micros(200));
    }

    #[test]
    fn percentiles_ordered() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.percentile(0.5);
        let p99 = h.percentile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= Duration::from_micros(256)); // ~512 bucket bound
        assert!(p99 <= Duration::from_micros(2048));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.percentile(0.99), Duration::ZERO);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::default();
        m.record_batch(4);
        m.record_batch(8);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
        let snap = m.snapshot();
        assert_eq!(snap.i64_field("batches").unwrap(), 2);
    }

    #[test]
    fn percentile_zero_answers_first_occupied_bucket() {
        // q = 0.0 on a non-empty histogram must reflect the smallest
        // recorded sample's bucket, not the histogram floor (1–2 µs)
        let h = Histogram::default();
        h.record(Duration::from_micros(300)); // bucket [256, 512)
        assert_eq!(h.percentile(0.0), Duration::from_micros(512));
        assert_eq!(h.percentile(1.0), Duration::from_micros(512));
    }

    #[test]
    fn samples_above_top_bucket_saturate_not_panic() {
        // the top bucket is [2^24, 2^25) µs ≈ 16.8–33.5 s; anything larger
        // (a stalled request, a wedged backend) lands there
        let h = Histogram::default();
        h.record(Duration::from_secs(40));
        h.record(Duration::from_secs(3600));
        assert_eq!(h.count(), 2);
        let cap = Duration::from_micros(1 << NBUCKETS);
        assert_eq!(h.percentile(0.5), cap);
        assert_eq!(h.percentile(0.99), cap);
        assert!(h.mean() >= Duration::from_secs(40));
    }

    #[test]
    fn percentile_is_monotone_in_q() {
        let h = Histogram::default();
        let mut x = 88172645463325252u64; // xorshift64
        for _ in 0..5000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            h.record(Duration::from_micros(1 + x % 3_000_000));
        }
        let mut last = Duration::ZERO;
        for i in 0..=100u32 {
            let q = f64::from(i) / 100.0;
            let p = h.percentile(q);
            assert!(
                p >= last,
                "percentile must be monotone in q: p({q}) = {p:?} < {last:?}"
            );
            last = p;
        }
    }

    #[test]
    fn snapshot_exposes_policy_and_pool_fields() {
        let m = Metrics::default();
        let snap = m.snapshot();
        // the fields exist and are well-typed even before any pooled
        // solve ran (pool stats default to zero, never spawn the pool)
        assert!(snap.i64_field("policy_seq").unwrap() >= 0);
        assert!(snap.i64_field("policy_fused").unwrap() >= 0);
        assert!(snap.i64_field("policy_pooled").unwrap() >= 0);
        assert!(snap.i64_field("policy_simd").unwrap() >= 0);
        assert!(snap.get("policy_calibrated").unwrap().as_bool().is_some());
        assert!(snap.i64_field("exec_pool_threads").unwrap() >= 0);
        assert!(snap.i64_field("exec_pool_solves").unwrap() >= 0);
        assert!(snap.i64_field("exec_pool_active").unwrap() >= 0);
        assert!(snap.i64_field("exec_pool_contended").unwrap() >= 0);
        // certifier verdict counters ride every snapshot (process-wide,
        // monotone — other tests in this binary may have bumped them)
        assert!(snap.i64_field("certified").unwrap() >= 0);
        assert!(snap.i64_field("cert_rejected").unwrap() >= 0);
    }

    #[test]
    fn shed_counter_in_snapshot() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().i64_field("shed").unwrap(), 0);
        m.shed.fetch_add(3, Ordering::Relaxed);
        assert_eq!(m.snapshot().i64_field("shed").unwrap(), 3);
    }

    #[test]
    fn fault_counters_in_snapshot() {
        let m = Metrics::default();
        let snap = m.snapshot();
        assert_eq!(snap.i64_field("timeouts").unwrap(), 0);
        assert_eq!(snap.i64_field("panics").unwrap(), 0);
        assert_eq!(snap.i64_field("rejected_too_large").unwrap(), 0);
        m.timeouts.fetch_add(2, Ordering::Relaxed);
        m.panics.fetch_add(1, Ordering::Relaxed);
        m.rejected_too_large.fetch_add(5, Ordering::Relaxed);
        let snap = m.snapshot();
        assert_eq!(snap.i64_field("timeouts").unwrap(), 2);
        assert_eq!(snap.i64_field("panics").unwrap(), 1);
        assert_eq!(snap.i64_field("rejected_too_large").unwrap(), 5);
    }

    #[test]
    fn inflight_gauge_saturates_at_zero() {
        let m = Metrics::default();
        m.dec_inflight(); // un-counted path: must clamp, not wrap
        assert_eq!(m.inflight.load(Ordering::Relaxed), 0);
        m.inflight.fetch_add(2, Ordering::Relaxed);
        m.dec_inflight();
        assert_eq!(m.inflight.load(Ordering::Relaxed), 1);
        assert_eq!(m.snapshot().i64_field("inflight").unwrap(), 1);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(Metrics::default());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for i in 0..1000 {
                        m.latency.record(Duration::from_micros(i + 1));
                        m.requests.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(m.latency.count(), 4000);
        assert_eq!(m.requests.load(Ordering::Relaxed), 4000);
    }
}
