//! Hand-rolled `epoll(7)` readiness reactor: the coordinator's
//! nonblocking event loop (Linux only, zero dependencies — the four
//! syscall wrappers are declared here against the libc `std` already
//! links).
//!
//! One thread owns the listener and every connection socket.  Sockets
//! are nonblocking; per-connection read buffers tolerate request lines
//! split at any byte boundary (and coalesce pipelined requests), and
//! per-connection write buffers tolerate partial writes at any byte
//! boundary.  Completed request lines are handed to the same
//! `handle_line` the blocking path uses — through the shared batcher,
//! admission gate, and router — so replies are byte-identical between
//! the two server modes.
//!
//! Replies (and streaming `progress` / `solution` / `result` frames)
//! come back over a completion channel tagged with the connection id
//! ([`crate::coordinator::batcher::ReplySink::Reactor`]); worker threads
//! wake the reactor by writing one byte to a self-pipe
//! ([`UnixStream::pair`]) registered in the epoll set.  A connection
//! that dies mid-stream is dropped from the table: later completions
//! for its id have nowhere to go and are discarded, while its
//! queued-but-unsolved requests are shed by the batcher's deadline
//! machinery with typed `timeout` replies.  A connection that merely
//! *half-closes* (peer FIN after sending requests) keeps its entry
//! until every in-flight request has delivered its terminal reply —
//! the same half-open semantics the blocking path's writer thread
//! provides.
//!
//! The blocking path's connection hygiene carries over: a partially
//! received request line that stalls longer than the configured bound
//! drops the connection (slow-loris guard), idle keep-alive connections
//! (empty read buffer) live forever, and a peer that stops reading is
//! disconnected once its write buffer stalls past `WRITE_STALL`.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, ReplySink};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::handle_line;
use crate::Result;

/// Readiness: data to read.
const EPOLLIN: u32 = 0x001;
/// Readiness: socket accepts writes.
const EPOLLOUT: u32 = 0x004;
/// Error condition (always reported; never needs arming).
const EPOLLERR: u32 = 0x008;
/// Hang-up (always reported; never needs arming).
const EPOLLHUP: u32 = 0x010;
/// Peer shut down its write half (must be armed explicitly).
const EPOLLRDHUP: u32 = 0x2000;
const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
/// `EPOLL_CLOEXEC`: the epoll fd must not leak into spawned processes.
const EPOLL_CLOEXEC: i32 = 0x8_0000;

/// Kernel ABI layout of `struct epoll_event`.  On x86-64 the kernel
/// packs it (no padding between `events` and `data`); other
/// architectures use natural alignment.
#[cfg(target_arch = "x86_64")]
#[repr(C, packed)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

/// Kernel ABI layout of `struct epoll_event` (naturally aligned
/// architectures).
#[cfg(not(target_arch = "x86_64"))]
#[repr(C)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
    fn close(fd: i32) -> i32;
}

/// Owned epoll instance; the fd is closed on drop.
struct Epoll {
    fd: RawFd,
}

impl Epoll {
    fn new() -> Result<Epoll> {
        // SAFETY: epoll_create1 takes no pointers; a negative return is
        // surfaced as the OS error before the fd is ever used.
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error().into());
        }
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> Result<()> {
        let mut ev = EpollEvent {
            events,
            data: token,
        };
        // SAFETY: `ev` is a live, exclusively borrowed epoll_event for
        // the duration of the call; the kernel copies it before
        // returning (and ignores it entirely for EPOLL_CTL_DEL).
        let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(std::io::Error::last_os_error().into());
        }
        Ok(())
    }

    /// Wait for readiness; returns how many entries of `events` were
    /// filled.  `EINTR` (and any other error) is treated as an empty
    /// timeout tick — the caller's loop re-enters with fresh state.
    fn wait(&self, events: &mut [EpollEvent], timeout: Duration) -> usize {
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        // SAFETY: the out-pointer and capacity describe `events`
        // exactly; the kernel writes at most `events.len()` entries and
        // the returned count is clamped to the slice length before use.
        let n = unsafe { epoll_wait(self.fd, events.as_mut_ptr(), events.len() as i32, ms) };
        if n < 0 {
            return 0;
        }
        (n as usize).min(events.len())
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is a live epoll fd owned by this struct and
        // never used after drop.
        unsafe { close(self.fd) };
    }
}

/// Epoll token of the TCP listener.
const LISTENER_TOKEN: u64 = 0;
/// Epoll token of the self-pipe's read end.
const WAKE_TOKEN: u64 = 1;
/// First connection token; connection ids count up from here.
const FIRST_CONN_TOKEN: u64 = 2;

/// Poll tick when nothing is ready: bounds how late the stall sweeps
/// (slow-loris, write-stall) and the stop flag can be observed.
const TICK: Duration = Duration::from_millis(100);
/// A peer that stops reading cannot park replies forever: once the
/// write buffer has stalled (no bytes accepted) this long, the
/// connection is dropped.  Mirrors the blocking path's write timeout.
const WRITE_STALL: Duration = Duration::from_secs(10);
/// Bounded window for flushing buffered replies at shutdown, after the
/// batcher and pool drains have answered everything in flight.
const SHUTDOWN_FLUSH: Duration = Duration::from_secs(2);

/// Per-connection state: the nonblocking socket plus framed read/write
/// buffers that tolerate partial I/O at any byte boundary.
struct Conn {
    stream: TcpStream,
    /// Bytes received but not yet terminated by `\n`.
    read_buf: Vec<u8>,
    /// Encoded reply lines (newline-terminated) not yet accepted by the
    /// socket.
    write_buf: Vec<u8>,
    /// Slow-loris clock: set while `read_buf` holds a partial line,
    /// cleared when the line completes or the buffer drains.
    line_started: Option<Instant>,
    /// Write-stall clock: set while the socket refuses bytes with a
    /// non-empty `write_buf`.
    write_started: Option<Instant>,
    /// Peer sent FIN (or erred): stop reading, flush what is buffered,
    /// then close.
    closing: bool,
    /// Request lines dispatched but not yet terminally answered; a
    /// `closing` connection is retired only once this reaches zero (and
    /// the write buffer drains), so half-open peers still get replies.
    pending: usize,
}

impl Conn {
    /// The epoll interest set for the current buffer state.
    fn interest(&self) -> u32 {
        let mut ev = EPOLLIN | EPOLLRDHUP;
        if !self.write_buf.is_empty() {
            ev |= EPOLLOUT;
        }
        ev
    }
}

/// Handle to the running reactor thread; [`Reactor::stop_and_join`]
/// flushes buffered replies (bounded) and closes every socket.
pub struct Reactor {
    stop: Arc<AtomicBool>,
    /// Wakes the reactor thread out of `epoll_wait` (self-pipe write);
    /// shared with every [`ReplySink::Reactor`] the reactor hands out.
    wake: Arc<dyn Fn() + Send + Sync>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Reactor {
    /// Take ownership of a (nonblocking) listener and serve it on a
    /// dedicated `pipedp-reactor` thread until [`Reactor::stop_and_join`].
    pub fn start(
        listener: TcpListener,
        batcher: Arc<Batcher>,
        metrics: Arc<Metrics>,
        line_stall: Duration,
    ) -> Result<Reactor> {
        let (wake_rx, wake_tx) = UnixStream::pair()?;
        wake_rx.set_nonblocking(true)?;
        wake_tx.set_nonblocking(true)?;
        let wake: Arc<dyn Fn() + Send + Sync> = Arc::new(move || {
            // one byte per wake; a full pipe already guarantees a wake,
            // and a closed read end (reactor exited) is harmless
            let _ = (&wake_tx).write(&[1u8]);
        });
        let stop = Arc::new(AtomicBool::new(false));
        let inner_stop = stop.clone();
        let inner_wake = wake.clone();
        let handle = std::thread::Builder::new()
            .name("pipedp-reactor".into())
            .spawn(move || {
                run(
                    listener,
                    wake_rx,
                    inner_wake,
                    inner_stop,
                    batcher,
                    metrics,
                    line_stall,
                );
            })
            .expect("spawn reactor thread");
        Ok(Reactor {
            stop,
            wake,
            handle: Mutex::new(Some(handle)),
        })
    }

    /// Signal the loop to exit, wake it, and join the thread.  The loop
    /// flushes already-buffered replies within `SHUTDOWN_FLUSH` and
    /// closes every socket before returning.  Idempotent.
    pub fn stop_and_join(&self) {
        self.stop.store(true, Ordering::SeqCst);
        (self.wake)();
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// The event loop.  Single-threaded socket ownership: every read,
/// write, and close of every connection happens here; worker threads
/// only enqueue `(conn, line, terminal)` completions and poke the
/// self-pipe.
fn run(
    listener: TcpListener,
    wake_rx: UnixStream,
    wake: Arc<dyn Fn() + Send + Sync>,
    stop: Arc<AtomicBool>,
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    line_stall: Duration,
) {
    let epoll = match Epoll::new() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("pipedp-reactor: epoll unavailable: {e}");
            return;
        }
    };
    let (done_tx, done_rx) = mpsc::channel::<(u64, String, bool)>();
    if epoll
        .ctl(EPOLL_CTL_ADD, listener.as_raw_fd(), EPOLLIN, LISTENER_TOKEN)
        .is_err()
        || epoll
            .ctl(EPOLL_CTL_ADD, wake_rx.as_raw_fd(), EPOLLIN, WAKE_TOKEN)
            .is_err()
    {
        eprintln!("pipedp-reactor: cannot register listener/self-pipe");
        return;
    }
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token = FIRST_CONN_TOKEN;
    let mut events = [EpollEvent { events: 0, data: 0 }; 64];
    let mut wake_sink = [0u8; 64];
    loop {
        // 1. move finished replies into their connections' write buffers
        //    and push opportunistically (the socket is usually writable)
        while let Ok((id, line, terminal)) = done_rx.try_recv() {
            if let Some(conn) = conns.get_mut(&id) {
                if terminal {
                    conn.pending = conn.pending.saturating_sub(1);
                }
                conn.write_buf.extend_from_slice(line.as_bytes());
                conn.write_buf.push(b'\n');
                if flush_writes(conn) {
                    let _ = epoll.ctl(EPOLL_CTL_MOD, conn.stream.as_raw_fd(), conn.interest(), id);
                } else {
                    close_conn(&epoll, &mut conns, id);
                }
            }
            // unknown id: the connection died mid-flight; drop the line
        }
        // 2. closing connections with nothing buffered and nothing in
        //    flight are done
        let drained: Vec<u64> = conns
            .iter()
            .filter(|(_, c)| c.closing && c.write_buf.is_empty() && c.pending == 0)
            .map(|(&id, _)| id)
            .collect();
        for id in drained {
            close_conn(&epoll, &mut conns, id);
        }
        if stop.load(Ordering::SeqCst) {
            shutdown_flush(&done_rx, &mut conns);
            return;
        }
        // 3. wait for readiness (bounded tick so stall sweeps run)
        let n = epoll.wait(&mut events, TICK);
        for ev in &events[..n] {
            let token = ev.data; // copy out: the struct may be packed
            let bits = ev.events;
            match token {
                LISTENER_TOKEN => accept_all(&epoll, &listener, &mut conns, &mut next_token),
                WAKE_TOKEN => {
                    while matches!((&wake_rx).read(&mut wake_sink), Ok(n) if n > 0) {}
                }
                id => handle_conn_event(
                    &epoll,
                    &mut conns,
                    id,
                    bits,
                    &batcher,
                    &metrics,
                    &done_tx,
                    &wake,
                ),
            }
        }
        // 4. stall sweeps: slow-loris on partial request lines, write
        //    stall on peers that stopped reading
        let now = Instant::now();
        let stalled: Vec<u64> = conns
            .iter()
            .filter(|(_, c)| {
                let read_stalled = c
                    .line_started
                    .is_some_and(|t0| now.duration_since(t0) >= line_stall);
                let write_stalled = c
                    .write_started
                    .is_some_and(|t0| now.duration_since(t0) >= WRITE_STALL);
                read_stalled || write_stalled
            })
            .map(|(&id, _)| id)
            .collect();
        for id in stalled {
            close_conn(&epoll, &mut conns, id);
        }
    }
}

/// Accept every pending connection (edge exhaustion: the listener is
/// level-triggered but accepting until `WouldBlock` costs one syscall
/// and keeps the loop simple).
fn accept_all(
    epoll: &Epoll,
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let token = *next_token;
                *next_token += 1;
                let interest = EPOLLIN | EPOLLRDHUP;
                if epoll
                    .ctl(EPOLL_CTL_ADD, stream.as_raw_fd(), interest, token)
                    .is_err()
                {
                    continue; // fd pressure: drop rather than park
                }
                conns.insert(
                    token,
                    Conn {
                        stream,
                        read_buf: Vec::new(),
                        write_buf: Vec::new(),
                        line_started: None,
                        write_started: None,
                        closing: false,
                        pending: 0,
                    },
                );
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(_) => return,
        }
    }
}

/// Deregister, close, and forget one connection.  Dropping the
/// `TcpStream` closes the fd; pending completions for this id are
/// discarded when they surface.
fn close_conn(epoll: &Epoll, conns: &mut HashMap<u64, Conn>, id: u64) {
    if let Some(conn) = conns.remove(&id) {
        let _ = epoll.ctl(EPOLL_CTL_DEL, conn.stream.as_raw_fd(), 0, 0);
    }
}

/// Dispatch one epoll event for connection `id`: read newly arrived
/// bytes into complete request lines, flush the write buffer, and close
/// on error/hang-up once the buffer drains.
#[allow(clippy::too_many_arguments)]
fn handle_conn_event(
    epoll: &Epoll,
    conns: &mut HashMap<u64, Conn>,
    id: u64,
    bits: u32,
    batcher: &Arc<Batcher>,
    metrics: &Arc<Metrics>,
    done_tx: &mpsc::Sender<(u64, String, bool)>,
    wake: &Arc<dyn Fn() + Send + Sync>,
) {
    let Some(conn) = conns.get_mut(&id) else {
        return; // already closed this iteration
    };
    if bits & EPOLLERR != 0 {
        close_conn(epoll, conns, id);
        return;
    }
    if bits & EPOLLIN != 0
        && !conn.closing
        && !read_lines(conn, id, batcher, metrics, done_tx, wake)
    {
        conn.closing = true;
    }
    if bits & (EPOLLHUP | EPOLLRDHUP) != 0 {
        conn.closing = true;
    }
    if bits & EPOLLOUT != 0 && !flush_writes(conn) {
        close_conn(epoll, conns, id);
        return;
    }
    if conn.closing && conn.write_buf.is_empty() && conn.pending == 0 {
        close_conn(epoll, conns, id);
        return;
    }
    let interest = conn.interest();
    let fd = conn.stream.as_raw_fd();
    let _ = epoll.ctl(EPOLL_CTL_MOD, fd, interest, id);
}

/// Read until `WouldBlock`, slicing the buffer into complete request
/// lines and handing each to the shared [`handle_line`] path with a
/// reactor reply sink.  Returns `false` on EOF or a fatal read error
/// (including non-UTF-8 input, which the blocking path also treats as
/// fatal).
fn read_lines(
    conn: &mut Conn,
    id: u64,
    batcher: &Arc<Batcher>,
    metrics: &Arc<Metrics>,
    done_tx: &mpsc::Sender<(u64, String, bool)>,
    wake: &Arc<dyn Fn() + Send + Sync>,
) -> bool {
    let mut chunk = [0u8; 4096];
    let mut alive = true;
    loop {
        match conn.stream.read(&mut chunk) {
            Ok(0) => {
                alive = false;
                break;
            }
            Ok(n) => conn.read_buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                alive = false;
                break;
            }
        }
    }
    // slice out every complete line; leftover bytes stay buffered and
    // arm the slow-loris clock below
    while let Some(pos) = conn.read_buf.iter().position(|&b| b == b'\n') {
        let line_bytes: Vec<u8> = conn.read_buf.drain(..=pos).collect();
        let line = match std::str::from_utf8(&line_bytes) {
            Ok(s) => s.trim_end(),
            Err(_) => return false, // same contract as the blocking reader
        };
        if line.trim().is_empty() {
            continue;
        }
        let sink = ReplySink::Reactor {
            conn: id,
            tx: done_tx.clone(),
            wake: wake.clone(),
        };
        handle_line(line, batcher, metrics, sink);
        conn.pending += 1;
    }
    conn.line_started = if conn.read_buf.is_empty() {
        None
    } else {
        Some(conn.line_started.unwrap_or_else(Instant::now))
    };
    alive
}

/// Push buffered bytes into the socket until it refuses or the buffer
/// drains; maintains the write-stall clock.  Returns `false` on a fatal
/// write error.
fn flush_writes(conn: &mut Conn) -> bool {
    while !conn.write_buf.is_empty() {
        match conn.stream.write(&conn.write_buf) {
            Ok(0) => return false,
            Ok(n) => {
                conn.write_buf.drain(..n);
                conn.write_started = None;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                conn.write_started = Some(conn.write_started.unwrap_or_else(Instant::now));
                return true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    conn.write_started = None;
    true
}

/// Final bounded flush at shutdown: the batcher and pool drains already
/// answered everything in flight, so every reply is either in the
/// completion channel or a write buffer.  Deliver what the sockets will
/// take within [`SHUTDOWN_FLUSH`], then close everything.
fn shutdown_flush(done_rx: &mpsc::Receiver<(u64, String, bool)>, conns: &mut HashMap<u64, Conn>) {
    while let Ok((id, line, _)) = done_rx.try_recv() {
        if let Some(conn) = conns.get_mut(&id) {
            conn.write_buf.extend_from_slice(line.as_bytes());
            conn.write_buf.push(b'\n');
        }
    }
    let deadline = Instant::now() + SHUTDOWN_FLUSH;
    for (_, conn) in conns.drain() {
        if conn.write_buf.is_empty() {
            continue;
        }
        let remaining = deadline
            .saturating_duration_since(Instant::now())
            .max(Duration::from_millis(1));
        if conn.stream.set_nonblocking(false).is_err() {
            continue;
        }
        if conn.stream.set_write_timeout(Some(remaining)).is_err() {
            continue;
        }
        let mut stream = conn.stream;
        let _ = stream.write_all(&conn.write_buf);
        let _ = stream.flush();
    }
}
