//! Deterministic PRNG (SplitMix64 seeding + xoshiro256**), plus the
//! workload-distribution helpers the benchmarks use.
//!
//! Not cryptographic; chosen for reproducible benchmarks and property
//! tests.  The same seeds are used by the Python golden-file generator so
//! both languages see identical workloads.

/// xoshiro256** with SplitMix64 seed expansion.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn seeded(seed: u64) -> Self {
        // SplitMix64 to fill the state; never all-zero.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[range.start, range.end)` (end exclusive, must be
    /// non-empty).  Uses rejection sampling — unbiased.
    pub fn range(&mut self, range: std::ops::Range<i64>) -> i64 {
        assert!(range.start < range.end, "empty range");
        let span = (range.end - range.start) as u64;
        // rejection sampling to kill modulo bias
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return range.start + (v % span) as i64;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.range(0..n as i64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// `k` distinct values sampled from `[1, max]`, sorted strictly
    /// decreasing — i.e. a valid S-DP offset vector (Definition 1).
    pub fn offsets(&mut self, k: usize, max: i64) -> Vec<i64> {
        assert!(k as i64 <= max, "cannot draw {k} distinct offsets from [1, {max}]");
        // Floyd's algorithm for distinct sampling.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (max - k as i64 + 1)..=max {
            let t = self.range(1..j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        let mut v: Vec<i64> = chosen.into_iter().collect();
        v.reverse();
        v
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random sub-generator (for parallel workers with decorrelated streams).
    pub fn fork(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelated() {
        let x = Rng::seeded(1).next_u64();
        let y = Rng::seeded(2).next_u64();
        assert_ne!(x, y);
    }

    #[test]
    fn range_bounds() {
        let mut rng = Rng::seeded(3);
        for _ in 0..10_000 {
            let v = rng.range(-5..17);
            assert!((-5..17).contains(&v));
        }
    }

    #[test]
    fn range_hits_all_values() {
        let mut rng = Rng::seeded(4);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[rng.range(0..8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::seeded(5);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn offsets_are_valid_sdp_offsets() {
        let mut rng = Rng::seeded(6);
        for _ in 0..200 {
            let k = rng.index(10) + 1;
            let max = k as i64 + rng.range(0..40);
            let offs = rng.offsets(k, max);
            assert_eq!(offs.len(), k);
            assert!(offs.windows(2).all(|w| w[0] > w[1]), "{offs:?}");
            assert!(*offs.last().unwrap() >= 1);
            assert!(offs[0] <= max);
        }
    }

    #[test]
    fn offsets_full_range() {
        // k == max forces the consecutive worst case (Fig. 4)
        let mut rng = Rng::seeded(7);
        let offs = rng.offsets(5, 5);
        assert_eq!(offs, vec![5, 4, 3, 2, 1]);
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Rng::seeded(8);
        let mut v: Vec<i64> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
