//! ASCII table renderer for benchmark output (paper-style tables).

/// A simple right-aligned ASCII table with a header row.
///
/// ```
/// use pipedp::util::table::Table;
/// let mut t = Table::new(vec!["band", "SEQUENTIAL", "PIPELINE"]);
/// t.row(vec!["small".into(), "274".into(), "78".into()]);
/// let s = t.render();
/// assert!(s.contains("SEQUENTIAL"));
/// ```
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: Vec<&str>) -> Self {
        Table {
            header: header.into_iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &width {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], left_align_first: bool| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                if i == 0 && left_align_first {
                    s.push_str(&format!(" {}{} |", c, " ".repeat(pad)));
                } else {
                    s.push_str(&format!(" {}{} |", " ".repeat(pad), c));
                }
            }
            s
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, true));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, true));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }
}

/// Format a duration in adaptive units (ns/µs/ms/s).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Format a large count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "123456".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 6);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_bad_width() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(5)), "5 ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50 ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00 s");
    }

    #[test]
    fn counts_grouped() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(68_453), "68,453");
        assert_eq!(fmt_count(1_234_567), "1,234,567");
    }
}
