//! From-scratch utility substrates.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure, so the conveniences a project would normally pull from
//! crates.io (serde_json, clap, rand, prettytable) are implemented here
//! from first principles: [`json`], [`cli`], [`rng`], [`table`].

pub mod cli;
pub mod json;
pub mod rng;
pub mod table;
