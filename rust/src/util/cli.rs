//! Tiny declarative CLI flag parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and auto-generated `--help`.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// One registered flag.
struct Spec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    boolean: bool,
}

/// Declarative argument parser.
///
/// ```
/// use pipedp::util::cli::Args;
/// let args = Args::new("demo", "demo command")
///     .flag("n", "problem size", Some("64"))
///     .boolflag("verbose", "print more")
///     .parse_from(vec!["--n".into(), "128".into(), "--verbose".into()])
///     .unwrap();
/// assert_eq!(args.get_usize("n").unwrap(), 128);
/// assert!(args.get_bool("verbose"));
/// ```
pub struct Args {
    program: &'static str,
    about: &'static str,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Args {
            program,
            about,
            specs: Vec::new(),
            values: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Register a value flag with an optional default.
    pub fn flag(mut self, name: &'static str, help: &'static str, default: Option<&str>) -> Self {
        self.specs.push(Spec {
            name,
            help,
            default: default.map(|s| s.to_string()),
            boolean: false,
        });
        self
    }

    /// Register a boolean flag (present = true).
    pub fn boolflag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec {
            name,
            help,
            default: None,
            boolean: true,
        });
        self
    }

    /// Parse from an explicit vector (testing) — see [`Args::parse`] for
    /// process args.
    pub fn parse_from(mut self, argv: Vec<String>) -> Result<Args> {
        // seed defaults
        for s in &self.specs {
            if let Some(d) = &s.default {
                self.values.insert(s.name.to_string(), d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                eprintln!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| Error::InvalidProblem(format!("unknown flag --{name}")))?;
                let value = if spec.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else {
                    match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| {
                            Error::InvalidProblem(format!("--{name} needs a value"))
                        })?,
                    }
                };
                self.values.insert(name, value);
            } else {
                self.positional.push(arg);
            }
        }
        Ok(self)
    }

    /// Parse the process arguments after the subcommand.
    pub fn parse(self, argv: impl IntoIterator<Item = String>) -> Result<Args> {
        self.parse_from(argv.into_iter().collect())
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nflags:\n", self.program, self.about);
        for s in &self.specs {
            let default = s
                .default
                .as_deref()
                .map(|d| format!(" (default {d})"))
                .unwrap_or_default();
            out.push_str(&format!("  --{:<14} {}{}\n", s.name, s.help, default));
        }
        out
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.req(name)?
            .parse()
            .map_err(|_| Error::InvalidProblem(format!("--{name} must be a non-negative integer")))
    }

    pub fn get_i64(&self, name: &str) -> Result<i64> {
        self.req(name)?
            .parse()
            .map_err(|_| Error::InvalidProblem(format!("--{name} must be an integer")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.req(name)?
            .parse()
            .map_err(|_| Error::InvalidProblem(format!("--{name} must be a number")))
    }

    pub fn get_str(&self, name: &str) -> Result<&str> {
        self.req(name)
    }

    /// Comma-separated i64 list, e.g. `--offsets 7,5,2`.
    pub fn get_i64_list(&self, name: &str) -> Result<Vec<i64>> {
        self.req(name)?
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| Error::InvalidProblem(format!("--{name}: bad integer '{s}'")))
            })
            .collect()
    }

    fn req(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::InvalidProblem(format!("missing required flag --{name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Args {
        Args::new("t", "test")
            .flag("n", "size", Some("10"))
            .flag("op", "operator", None)
            .boolflag("fast", "go fast")
    }

    #[test]
    fn defaults_apply() {
        let a = base().parse_from(vec![]).unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 10);
        assert!(!a.get_bool("fast"));
        assert!(a.get_str("op").is_err());
    }

    #[test]
    fn space_and_equals_forms() {
        let a = base()
            .parse_from(vec!["--n".into(), "42".into(), "--op=min".into()])
            .unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 42);
        assert_eq!(a.get_str("op").unwrap(), "min");
    }

    #[test]
    fn bool_flag() {
        let a = base().parse_from(vec!["--fast".into()]).unwrap();
        assert!(a.get_bool("fast"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(base().parse_from(vec!["--wat".into()]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(base().parse_from(vec!["--n".into()]).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = base()
            .parse_from(vec!["pos1".into(), "--n".into(), "5".into(), "pos2".into()])
            .unwrap();
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn i64_list() {
        let a = base()
            .parse_from(vec!["--op".into(), "7,5, 2".into()])
            .unwrap();
        assert_eq!(a.get_i64_list("op").unwrap(), vec![7, 5, 2]);
    }
}
