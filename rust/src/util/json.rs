//! Minimal JSON parser and serializer (RFC 8259 subset).
//!
//! Used for the artifact manifest, the server wire protocol, and golden
//! files shared with the Python layer.  Supports the full JSON data model;
//! numbers are kept as `f64` with an `i64` fast path (all values we
//! exchange are integers or short floats, well inside `f64`'s exact range).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value.  Object keys are ordered (BTreeMap) so serialization is
/// deterministic — golden-file friendly.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn int(n: i64) -> Json {
        Json::Num(n as f64)
    }

    pub fn null() -> Json {
        Json::Null
    }

    /// Encode a log-probability that may legitimately be infinite.
    ///
    /// JSON has no `Infinity` token (the serializer degrades bare
    /// non-finite [`Json::Num`]s to `null`, which loses the sign), so the
    /// log-space DP families (`viterbi`, `cyk` — docs/PROTOCOL.md) carry
    /// `±∞` as the string sentinels `"-inf"` / `"inf"`.  Finite values
    /// stay plain numbers; `NaN` (never a valid log-probability —
    /// [`crate::core::problem::ViterbiProblem`] validation rejects it)
    /// encodes as `null` so it cannot masquerade as a score.
    pub fn lognum(v: f64) -> Json {
        if v == f64::NEG_INFINITY {
            Json::str("-inf")
        } else if v == f64::INFINITY {
            Json::str("inf")
        } else if v.is_nan() {
            Json::Null
        } else {
            Json::Num(v)
        }
    }

    // ---- accessors --------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like [`Json::get`] but a typed error instead of `None`.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing field '{key}'")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Decode a [`Json::lognum`] value: a plain finite number, or the
    /// `"-inf"` / `"inf"` string sentinels.  Anything else (including a
    /// non-finite `Num` smuggled in as `1e999`) is `None` — the sentinel
    /// spelling is the only accepted encoding of an infinity.
    pub fn as_lognum(&self) -> Option<f64> {
        match self {
            Json::Num(n) if n.is_finite() => Some(*n),
            Json::Str(s) if s == "-inf" => Some(f64::NEG_INFINITY),
            Json::Str(s) if s == "inf" => Some(f64::INFINITY),
            _ => None,
        }
    }

    /// Typed field accessors (error-reporting convenience for decoders).
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.field(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not a string")))
    }

    pub fn i64_field(&self, key: &str) -> Result<i64> {
        self.field(key)?
            .as_i64()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not an integer")))
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.field(key)?
            .as_usize()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not a usize")))
    }

    pub fn arr_field(&self, key: &str) -> Result<&[Json]> {
        self.field(key)?
            .as_arr()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not an array")))
    }

    /// Decode an array of i64 (e.g. offsets, dims vectors on the wire).
    pub fn i64_vec_field(&self, key: &str) -> Result<Vec<i64>> {
        self.arr_field(key)?
            .iter()
            .map(|v| {
                v.as_i64()
                    .ok_or_else(|| Error::Json(format!("'{key}' has a non-integer element")))
            })
            .collect()
    }

    pub fn lognum_field(&self, key: &str) -> Result<f64> {
        self.field(key)?
            .as_lognum()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not a lognum")))
    }

    /// Decode an array of [`Json::lognum`]s (log-probability vectors of
    /// the `viterbi`/`cyk` wire kinds, `−∞` spelled `"-inf"`).
    pub fn lognum_vec_field(&self, key: &str) -> Result<Vec<f64>> {
        self.arr_field(key)?
            .iter()
            .map(|v| {
                v.as_lognum()
                    .ok_or_else(|| Error::Json(format!("'{key}' has a non-lognum element")))
            })
            .collect()
    }

    // ---- serialization ----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // bare `inf`/`NaN` tokens are not JSON — no peer (nor
                    // our own parser) could read them back; `null` is the
                    // interoperable encoding of a non-value
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing ----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!(
                "trailing characters at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting the recursive-descent parser accepts.  The
/// parser recurses per `[`/`{`, so unbounded depth lets a wire request
/// like `"[[[[…"` overflow the stack (an abort, not a catchable error);
/// 128 levels is far beyond any legitimate payload.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    /// Guard one level of container recursion (decremented by the caller
    /// on success; errors abort the whole parse, so leaks don't matter).
    fn descend(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting deeper than 128 levels"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        self.descend()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        self.descend()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let code = self.hex4()?;
                        // Surrogate-pair handling.  A high surrogate must
                        // be followed by `\u` + a *low* surrogate: the seed
                        // computed `lo - 0xDC00` unchecked, so a malformed
                        // line like `"\ud800A"` underflowed (panic in
                        // debug, garbage char in release) inside the
                        // server's per-connection decoder.
                        let c = if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else if (0xDC00..0xE000).contains(&code) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            char::from_u32(code)
                        };
                        s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut code = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            code = code * 16 + d;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.field("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.str_field("c").unwrap(), "x");
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\ slash ünïcødé 🚀";
        let j = Json::str(s);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escape_surrogate_pair() {
        let v = Json::parse(r#""🚀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "🚀");
    }

    #[test]
    fn roundtrip_random_structures() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seeded(42);
        for _ in 0..200 {
            let v = random_json(&mut rng, 3);
            let text = v.to_string();
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, v, "roundtrip failed for {text}");
        }
    }

    fn random_json(rng: &mut crate::util::rng::Rng, depth: usize) -> Json {
        let choice = if depth == 0 {
            rng.range(0..4)
        } else {
            rng.range(0..6)
        };
        match choice {
            0 => Json::Null,
            1 => Json::Bool(rng.range(0..2) == 0),
            2 => Json::int(rng.range(-1_000_000..1_000_000)),
            3 => Json::str(format!("s{}", rng.range(0..1000))),
            4 => Json::Arr(
                (0..rng.range(0..4))
                    .map(|_| random_json(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.range(0..4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "\"unterminated", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rejects_malformed_surrogates_without_panicking() {
        // regression for the wire-reachable underflow: a high surrogate
        // followed by a non-low `\u` escape computed `lo - 0xDC00` on
        // lo = 0x41 (debug panic / release garbage char)
        for bad in [
            r#""\ud800\u0041""#, // the underflow case: lo = 0x41 < 0xDC00
            r#""\ud800A""#,      // high surrogate, no second escape
            r#""\ud800""#,       // high surrogate at end of string
            r#""\ud800\n""#,     // high surrogate then a non-\u escape
            r#""\ud800\ud800""#, // high followed by another high
            r#""\udc00""#,       // lone low surrogate
            r#""\udfff x""#,     // lone low surrogate mid-string
            r#""\ud8""#,         // truncated hex
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
        // a valid pair still decodes
        assert_eq!(
            Json::parse(r#""😀""#).unwrap().as_str().unwrap(),
            "😀"
        );
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // regression: `{n}` Display emitted bare `inf`/`NaN` tokens no
        // parser (including ours) accepts
        for v in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let line = Json::Num(v).to_string();
            assert_eq!(line, "null", "{v} must not leak into the output");
            // the roundtrip stays parseable end-to-end
            assert_eq!(Json::parse(&line).unwrap(), Json::Null);
        }
        // …and inside containers
        let doc = Json::obj(vec![("x", Json::Num(f64::NAN)), ("y", Json::int(3))]);
        let text = doc.to_string();
        assert_eq!(text, r#"{"x":null,"y":3}"#);
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        for (open, close) in [("[", "]"), ("{\"k\":", "}")] {
            // far past MAX_DEPTH: must come back as Err, not abort
            let deep = open.repeat(100_000) + &close.repeat(100_000);
            assert!(Json::parse(&deep).is_err());
            // truncated version (no closers) as well
            assert!(Json::parse(&open.repeat(100_000)).is_err());
        }
        // depths at and under the limit still parse
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&ok).is_ok());
        let over = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        assert!(Json::parse(&over).is_err());
        // sibling containers don't accumulate depth
        let siblings = format!("[{}]", vec!["[[1]]"; 200].join(","));
        assert!(Json::parse(&siblings).is_ok());
    }

    #[test]
    fn malformed_corpus_always_errors_never_panics() {
        let corpus = [
            // truncated escapes
            r#""\"#,
            r#""\u"#,
            r#""\u12"#,
            r#""\u12G4""#,
            r#""\x41""#,
            // lone / invalid surrogates (see the dedicated test too)
            r#""\udc00\ud800""#,
            r#"{"k": "\ud800 "}"#,
            // raw control characters in strings
            "\"a\u{1}b\"",
            "\"\t\"",
            // structural garbage
            "{\"a\":}",
            "[,]",
            "[1 2]",
            "{\"a\":1,}",
            "{1: 2}",
            "nul",
            "+1",
            "- 1",
            "--help",
            "\u{FEFF}{}", // BOM is not JSON whitespace
            "[\"closed\", ",
        ];
        for bad in corpus {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip_property_over_generated_trees() {
        use crate::prop::forall;
        forall("json roundtrip", 150, |g| {
            let v = gen_json(g, 4);
            let text = v.to_string();
            match Json::parse(&text) {
                Ok(back) if back == v => Ok(()),
                Ok(back) => Err(format!("{text} reparsed as {back:?}")),
                Err(e) => Err(format!("{text}: {e}")),
            }
        });
    }

    /// Generator for the roundtrip property: all scalar kinds (finite
    /// floats included), escapes-heavy and non-ASCII strings, nested
    /// containers.
    fn gen_json(g: &mut crate::prop::Gen, depth: usize) -> Json {
        let choice = if depth == 0 { g.usize(0..5) } else { g.usize(0..7) };
        match choice {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::int(g.i64(-1_000_000_000..1_000_000_000)),
            3 => {
                // finite float with a short exact decimal expansion
                let v = g.i64(-1_000_000..1_000_000) as f64 / 64.0;
                Json::Num(v)
            }
            4 => {
                let pool = [
                    "", "plain", "with \"quotes\"", "back\\slash", "tab\tnl\n",
                    "ünïcødé", "🚀🔧", "control\u{1}char", "line\rreturn",
                    "nul\u{0}byte",
                ];
                Json::str(*g.choose(&pool))
            }
            5 => Json::Arr((0..g.usize(0..4)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize(0..4))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn lognum_roundtrips_infinities_through_the_wire() {
        use crate::prop::forall;
        forall("lognum roundtrip", 200, |g| {
            // mix finite log-probs (≤ 0, as check_logprobs enforces) with
            // the infinities the plain Num encoding would destroy
            let v = match g.usize(0..4) {
                0 => f64::NEG_INFINITY,
                1 => 0.0,
                _ => -(g.i64(0..1_000_000) as f64) / 64.0,
            };
            let doc = Json::obj(vec![("p", Json::lognum(v))]);
            let back = Json::parse(&doc.to_string())
                .map_err(|e| format!("reparse: {e}"))?;
            let got = back
                .lognum_field("p")
                .map_err(|e| format!("decode: {e}"))?;
            if got == v || (got - v).abs() < 1e-12 {
                Ok(())
            } else {
                Err(format!("{v} came back as {got}"))
            }
        });
    }

    #[test]
    fn lognum_sentinels_and_rejections() {
        assert_eq!(Json::lognum(f64::NEG_INFINITY).to_string(), r#""-inf""#);
        assert_eq!(Json::lognum(f64::INFINITY).to_string(), r#""inf""#);
        assert_eq!(Json::lognum(f64::NAN), Json::Null, "NaN must not encode as a score");
        assert_eq!(Json::lognum(-1.5), Json::Num(-1.5));

        assert_eq!(Json::str("-inf").as_lognum(), Some(f64::NEG_INFINITY));
        assert_eq!(Json::str("inf").as_lognum(), Some(f64::INFINITY));
        assert_eq!(Json::Num(-2.25).as_lognum(), Some(-2.25));
        // only the sentinel spelling may carry an infinity
        assert_eq!(Json::parse("1e999").unwrap().as_lognum(), None);
        assert_eq!(Json::str("Infinity").as_lognum(), None);
        assert_eq!(Json::Null.as_lognum(), None);
        assert_eq!(Json::Bool(true).as_lognum(), None);

        let v = Json::parse(r#"{"a": [0, "-inf", -3.5]}"#).unwrap();
        assert_eq!(
            v.lognum_vec_field("a").unwrap(),
            vec![0.0, f64::NEG_INFINITY, -3.5]
        );
        let bad = Json::parse(r#"{"a": ["nan"]}"#).unwrap();
        assert!(bad.lognum_vec_field("a").is_err());
    }

    #[test]
    fn typed_field_errors() {
        let v = Json::parse(r#"{"n": "not-a-number"}"#).unwrap();
        assert!(v.i64_field("n").is_err());
        assert!(v.i64_field("missing").is_err());
        assert!(v.str_field("n").is_ok());
    }

    #[test]
    fn i64_vec_field() {
        let v = Json::parse(r#"{"a": [3, 2, 1]}"#).unwrap();
        assert_eq!(v.i64_vec_field("a").unwrap(), vec![3, 2, 1]);
        let bad = Json::parse(r#"{"a": [3, "x"]}"#).unwrap();
        assert!(bad.i64_vec_field("a").is_err());
    }
}
