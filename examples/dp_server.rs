//! **End-to-end validation driver** (EXPERIMENTS.md §E9): start the full
//! coordinator in-process, generate a realistic mixed workload, drive it
//! over real TCP through router → dynamic batcher → worker pool → PJRT
//! engine (AOT Pallas kernels) / native executors, and report throughput,
//! latency percentiles and batching efficiency.
//!
//! Run: `make artifacts && cargo run --release --example dp_server`
//! Flags: `-- [requests] [clients]` (defaults 400, 4).

use std::time::Instant;

use pipedp::coordinator::batcher::Policy;
use pipedp::coordinator::request::{Backend, Request, RequestBody};
use pipedp::coordinator::server::{Client, Config, Server};
use pipedp::core::problem::{McmProblem, SdpProblem};
use pipedp::core::schedule::McmVariant;
use pipedp::core::semigroup::Op;
use pipedp::util::rng::Rng;
use pipedp::util::table::{fmt_duration, Table};

fn main() -> pipedp::Result<()> {
    let mut args = std::env::args().skip(1);
    let total: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(400);
    let clients: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4);
    let have_artifacts = pipedp::runtime::artifacts_dir().join("manifest.json").exists();
    if !have_artifacts {
        eprintln!("NOTE: artifacts missing — everything will be served natively.");
    }

    let server = Server::start(Config {
        addr: "127.0.0.1:0".into(),
        workers: 4,
        policy: Policy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(2),
        },
        allow_engineless: true,
        warm: true,
        queue_cap: 0, // PIPEDP_POOL_QUEUE_CAP or the built-in default
        exec_threads: 0, // PIPEDP_EXEC_THREADS or available parallelism
    })?;
    println!("coordinator listening on {}", server.local_addr);
    // §Perf: without this, the first request per bucket pays PJRT compile
    // latency (p99 was 2.1 s); with warmup it drops to the batching window
    let warm_start = Instant::now();
    server.wait_ready(std::time::Duration::from_secs(60));
    println!("engine warm in {}", fmt_duration(warm_start.elapsed()));

    // ---- workload: 60% MCM (bursty same-bucket → batchable), 40% S-DP ----
    let make_request = |rng: &mut Rng, i: usize| -> Request {
        if rng.chance(0.6) {
            let n = *rng_choice(rng, &[8usize, 12, 16, 16, 16, 30]);
            Request {
                id: 0,
                body: RequestBody::Mcm {
                    problem: McmProblem::random(rng, n, 30),
                    variant: McmVariant::Corrected,
                },
                backend: Backend::Auto,
                full: false,
                want_solution: false,
            }
        } else {
            let k = 4 + (i % 3);
            let offsets = rng.offsets(k, 2 * k as i64);
            let a1 = offsets[0] as usize;
            let n = 200 + rng.index(800);
            let init: Vec<i64> = (0..a1).map(|_| rng.range(0..1000)).collect();
            Request {
                id: 0,
                body: RequestBody::Sdp(SdpProblem::new(n, offsets, Op::Min, init).unwrap()),
                backend: Backend::Auto,
                full: false,
                want_solution: false,
            }
        }
    };

    let addr = server.local_addr.to_string();
    let per_client = total / clients;
    let started = Instant::now();
    let mut verified = 0usize;
    let mut failures = 0usize;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let addr = addr.clone();
            handles.push(s.spawn(move || -> (usize, usize) {
                let mut rng = Rng::seeded(9000 + c as u64);
                let mut client = Client::connect(&addr).expect("connect");
                let mut ok = 0;
                let mut bad = 0;
                // pipelined bursts of 16 keep the batcher fed
                let mut sent = 0;
                while sent < per_client {
                    let burst = 16.min(per_client - sent);
                    let reqs: Vec<Request> =
                        (0..burst).map(|i| make_request(&mut rng, sent + i)).collect();
                    // independently compute the expected answers
                    let expected: Vec<i64> = reqs
                        .iter()
                        .map(|r| match &r.body {
                            RequestBody::Mcm { problem, .. } => pipedp::mcm::seq::cost(problem),
                            RequestBody::Sdp(p) => *pipedp::sdp::seq::solve(p).last().unwrap(),
                            RequestBody::Align(p) => pipedp::align::seq::score(p),
                            RequestBody::Stats => 0,
                        })
                        .collect();
                    let resps = client.call_pipelined(reqs).expect("pipelined call");
                    for (resp, want) in resps.iter().zip(&expected) {
                        if resp.ok && resp.value == *want {
                            ok += 1;
                        } else {
                            bad += 1;
                            eprintln!("MISMATCH: got {:?} want {want}", resp.value);
                        }
                    }
                    sent += burst;
                }
                (ok, bad)
            }));
        }
        for h in handles {
            let (ok, bad) = h.join().unwrap();
            verified += ok;
            failures += bad;
        }
    });
    let elapsed = started.elapsed();

    // ---- report -----------------------------------------------------------
    let m = &server.metrics;
    let served = verified + failures;
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["requests served".into(), served.to_string()]);
    t.row(vec!["answers verified vs oracle".into(), verified.to_string()]);
    t.row(vec!["failures".into(), failures.to_string()]);
    t.row(vec![
        "wall clock".into(),
        fmt_duration(elapsed),
    ]);
    t.row(vec![
        "throughput".into(),
        format!("{:.0} req/s", served as f64 / elapsed.as_secs_f64()),
    ]);
    t.row(vec![
        "latency p50 / p99".into(),
        format!(
            "{} / {}",
            fmt_duration(m.latency.percentile(0.5)),
            fmt_duration(m.latency.percentile(0.99))
        ),
    ]);
    t.row(vec![
        "queue wait p99".into(),
        fmt_duration(m.queue_wait.percentile(0.99)),
    ]);
    t.row(vec![
        "dispatches (batches)".into(),
        m.batches.load(std::sync::atomic::Ordering::Relaxed).to_string(),
    ]);
    t.row(vec![
        "mean batch size".into(),
        format!("{:.2}", m.mean_batch_size()),
    ]);
    println!("\n== dp_server end-to-end ({clients} clients × {per_client} requests) ==");
    println!("{}", t.render());
    if failures > 0 {
        std::process::exit(1);
    }
    println!("all {verified} responses verified against the sequential oracle ✓");
    Ok(())
}

fn rng_choice<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.index(xs.len())]
}
