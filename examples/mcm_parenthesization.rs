//! Matrix-chain optimization end-to-end: solve a chain, reconstruct the
//! optimal parenthesization, and audit the published pipeline schedule
//! against the corrected one on the same instance.
//!
//! Run: `cargo run --release --example mcm_parenthesization -- [dims…]`
//! e.g. `cargo run --release --example mcm_parenthesization -- 30 35 15 5 10 20 25`

use pipedp::core::conflict;
use pipedp::core::problem::McmProblem;
use pipedp::core::schedule::{McmSchedule, McmVariant};
use pipedp::util::table::Table;

fn main() -> pipedp::Result<()> {
    let dims: Vec<i64> = std::env::args()
        .skip(1)
        .filter_map(|s| s.parse().ok())
        .collect();
    let p = if dims.len() >= 2 {
        McmProblem::new(dims)?
    } else {
        McmProblem::clrs()
    };
    let n = p.n();
    println!("chain: {} matrices, dims {:?}\n", n, p.dims);

    // the classic DP answer + reconstruction
    let cost = pipedp::mcm::seq::cost(&p);
    println!("optimal cost            : {cost} scalar multiplications");
    println!(
        "optimal parenthesization: {}\n",
        pipedp::mcm::seq::parenthesization(&p)
    );

    // audit both pipeline schedules on this instance
    let mut t = Table::new(vec![
        "schedule",
        "steps",
        "width",
        "Thm.1 conflicts",
        "staleness hazards",
        "cost computed",
        "correct?",
    ]);
    for variant in [McmVariant::PaperFaithful, McmVariant::Corrected] {
        let sched = McmSchedule::compile(n, variant);
        let got = *pipedp::mcm::pipeline::execute(&p, &sched).last().unwrap();
        t.row(vec![
            variant.name().into(),
            sched.num_steps().to_string(),
            sched.max_width().to_string(),
            conflict::analyze_mcm(&sched).conflicted_substeps.to_string(),
            conflict::mcm_hazards(&sched).len().to_string(),
            got.to_string(),
            if got == cost { "yes".into() } else { "NO ⚠".into() },
        ]);
    }
    println!("{}", t.render());

    println!("\nfirst pipeline steps (corrected schedule):");
    print!("{}", pipedp::mcm::pipeline::trace(&p, McmVariant::Corrected, 6));

    // the documented counterexample, for good measure
    let bad = McmProblem::hazard_counterexample();
    let f = *pipedp::mcm::pipeline::solve(&bad, McmVariant::PaperFaithful)
        .last()
        .unwrap();
    println!(
        "\ncounterexample {:?}: published schedule → {}, truth → {} (DESIGN.md §1.1)",
        bad.dims,
        f,
        pipedp::mcm::seq::cost(&bad)
    );
    Ok(())
}
