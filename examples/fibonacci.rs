//! The paper's Definition 1 example end-to-end: Fibonacci as an S-DP
//! instance, with the Fig. 3-style pipeline trace and a step-count
//! comparison across the paper's algorithms on the GPU cost model.
//!
//! Run: `cargo run --release --example fibonacci -- [n]`

use pipedp::core::problem::SdpProblem;
use pipedp::simulator::{self, GpuModel};

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let p = SdpProblem::fibonacci(n);

    println!("Fibonacci as S-DP: k=2, a=(2,1), ⊗=+, ST[0]=ST[1]=1\n");
    print!("{}", pipedp::sdp::pipeline::trace(&p, 8));

    let st = pipedp::sdp::pipeline::solve(&p);
    println!("\nST = {:?}", &st[..n.min(16)]);
    println!("fib({n}) = {}", st[n - 1]);

    // paper cost models, priced on the GPU simulator
    let model = GpuModel::default();
    let k = p.k() as u64;
    let rows = [
        (
            "SEQUENTIAL (Fig. 1, host)",
            simulator::exec::simulate_cpu(&model, &simulator::sequential_trace(n as u64, k)).total,
        ),
        (
            "NAIVE-PARALLEL (§II-B)",
            simulator::simulate(&model, &simulator::naive_trace(n as u64, k)).total,
        ),
        (
            "PREFIX (§II-B)",
            simulator::simulate(&model, &simulator::prefix_trace(n as u64, k)).total,
        ),
        (
            "PIPELINE (Fig. 2)",
            simulator::simulate(&model, &simulator::pipeline_trace(&p)).total,
        ),
    ];
    println!("\nmodeled cycles (GPU cost model; tiny n — launch overhead dominates):");
    for (name, cycles) in rows {
        println!("  {name:28} {cycles:>10} cycles");
    }
    println!("\nnote: a=(2,1) is a consecutive run (the Fig. 4 pattern): the pipeline");
    println!("pays a 2-way read collision every step; the 2-by-2 variant halves it.");
}
