//! Quickstart: the five-minute tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`
//! (XLA steps need `make artifacts` first; they are skipped otherwise.)

use pipedp::core::problem::{McmProblem, SdpProblem};
use pipedp::core::schedule::McmVariant;
use pipedp::core::semigroup::Op;
use pipedp::runtime::engine::Engine;

fn main() -> pipedp::Result<()> {
    // --- 1. S-DP problems (Definition 1) --------------------------------
    // Fibonacci is the paper's own example: k=2, a=(2,1), ⊗=+.
    let fib = SdpProblem::fibonacci(32);
    let st = pipedp::sdp::pipeline::solve(&fib);
    println!("fib(32) via Fig. 2 pipeline        = {}", st[31]);

    // A min-recurrence with three offsets, four executors, one answer.
    let p = SdpProblem::new(64, vec![7, 5, 2], Op::Min, vec![9, 4, 6, 1, 8, 2, 7])?;
    let seq = pipedp::sdp::seq::solve(&p);
    assert_eq!(pipedp::sdp::pipeline::solve(&p), seq);
    assert_eq!(pipedp::sdp::prefix::solve(&p), seq);
    assert_eq!(pipedp::sdp::two_by_two::solve(&p), seq);
    println!(
        "S-DP n=64 k=3 min                  = {}   (4 executors agree)",
        seq[63]
    );

    // --- 2. Matrix-chain multiplication (§IV) ----------------------------
    let clrs = McmProblem::clrs();
    let table = pipedp::mcm::pipeline::solve(&clrs, McmVariant::Corrected);
    println!(
        "CLRS chain optimal cost            = {}   ({})",
        table.last().unwrap(),
        pipedp::mcm::seq::parenthesization(&clrs)
    );

    // The published Fig. 8 schedule is unsound for n ≥ 4 (DESIGN.md §1.1):
    let bad = McmProblem::hazard_counterexample();
    let faithful = pipedp::mcm::pipeline::solve(&bad, McmVariant::PaperFaithful);
    let truth = pipedp::mcm::seq::cost(&bad);
    println!(
        "published schedule on {:?}: {} (true optimum {})",
        bad.dims,
        faithful.last().unwrap(),
        truth
    );

    // --- 3. The same computations through AOT Pallas kernels on PJRT -----
    if pipedp::runtime::artifacts_dir().join("manifest.json").exists() {
        let engine = Engine::load()?;
        let xla_table = engine.solve_mcm(&clrs)?;
        assert_eq!(xla_table, table);
        println!(
            "XLA (Pallas kernel via PJRT)       = {}   ✓ matches native",
            xla_table.last().unwrap()
        );
    } else {
        println!("(run `make artifacts` to enable the XLA backend)");
    }

    // --- 4. Conflict analysis (the paper's §III-A cost model) ------------
    let sched = pipedp::core::schedule::SdpSchedule::new(p.n, p.offsets.clone());
    let report = pipedp::core::conflict::analyze_sdp(&sched);
    println!(
        "conflict analysis: max degree {} over {} steps (1 = conflict-free)",
        report.max_degree, report.steps
    );
    Ok(())
}
