//! Sequence-alignment traceback end-to-end: solve all three variants
//! over the recording wavefront pipeline, reconstruct the edit script /
//! aligned pairs / local span, and replay each script to prove it
//! reproduces the reported score (DESIGN.md §8).
//!
//! Run: `cargo run --release --example align_traceback -- [a…] -- [b…]`
//! e.g. `cargo run --release --example align_traceback -- 1 2 3 4 7 -- 2 3 9 4`

use pipedp::align::{seq, wavefront};
use pipedp::core::problem::{AlignProblem, AlignScoring, AlignVariant};
use pipedp::core::traceback;
use pipedp::util::table::Table;

fn main() -> pipedp::Result<()> {
    // two symbol lists separated by a bare `--`
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (a, b): (Vec<i64>, Vec<i64>) = match args.iter().position(|s| s == "--") {
        Some(split) => (
            args[..split].iter().filter_map(|s| s.parse().ok()).collect(),
            args[split + 1..].iter().filter_map(|s| s.parse().ok()).collect(),
        ),
        None => (Vec::new(), Vec::new()),
    };
    let (a, b): (Vec<i64>, Vec<i64>) = if a.is_empty() || b.is_empty() {
        // LCS("ABCBDAB", "BDCABA") textbook pair, symbol-encoded
        (vec![1, 2, 3, 2, 4, 1, 2], vec![2, 4, 3, 1, 2, 1])
    } else {
        (a, b)
    };
    println!("a = {a:?}\nb = {b:?}\n");

    let mut t = Table::new(vec![
        "variant",
        "score",
        "script",
        "span a",
        "span b",
        "pairs",
        "replay ok?",
    ]);
    for variant in AlignVariant::ALL {
        let p = AlignProblem::new(a.clone(), b.clone(), variant, AlignScoring::default())?;
        // the recording wavefront executor fills the 2-bit move sidecar
        // alongside the table; reconstruction walks it back
        let (st, moves) = wavefront::solve_recorded(&p);
        let sol = traceback::align_solution(&p, &st, &moves);
        let replay_ok = sol.score == seq::score(&p);
        t.row(vec![
            variant.name().into(),
            sol.score.to_string(),
            sol.ops.clone(),
            format!("[{}..{}]", sol.start.0, sol.end.0),
            format!("[{}..{}]", sol.start.1, sol.end.1),
            sol.pairs.len().to_string(),
            if replay_ok { "yes".into() } else { "NO ⚠".into() },
        ]);
    }
    println!("{}", t.render());
    println!(
        "\nscript ops: M aligned match, S aligned substitution, D consume a[i], \
         I consume b[j]; spans are the traced window (whole sequences for \
         lcs/edit, the optimal local window for local)."
    );

    // the same reconstruction over the wire: {"kind": "align",
    // "want_solution": true} — see docs/PROTOCOL.md
    let p = AlignProblem::lcs(a, b)?;
    let sol = traceback::align_solution_from_table(&p, &seq::solve(&p));
    println!(
        "\nwire shape (docs/PROTOCOL.md): {}",
        sol.to_json().to_string()
    );
    Ok(())
}
