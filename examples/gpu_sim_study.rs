//! GPU cost-model study: reproduce Table I's three bands, then sweep the
//! conflict spectrum (the Fig. 4 pathology) and the MCM step-count claim.
//!
//! Run: `cargo run --release --example gpu_sim_study`

use pipedp::core::problem::SdpProblem;
use pipedp::core::schedule::{McmSchedule, McmVariant};
use pipedp::core::semigroup::Op;
use pipedp::simulator::{self, calibrate, GpuModel};
use pipedp::util::rng::Rng;
use pipedp::util::table::Table;

fn main() {
    let model = GpuModel::default();

    // --- Table I ----------------------------------------------------------
    println!("== Table I (paper ms vs modeled ms, mean of 10 draws/band) ==");
    let mut t = Table::new(vec!["band", "SEQ", "SEQ'", "NAIVE", "NAIVE'", "PIPE", "PIPE'"]);
    for (name, paper, modeled) in calibrate::shape_report(&model, 10) {
        t.row(vec![
            name,
            format!("{:.0}", paper[0]),
            format!("{:.0}", modeled[0]),
            format!("{:.0}", paper[1]),
            format!("{:.0}", modeled[1]),
            format!("{:.0}", paper[2]),
            format!("{:.0}", modeled[2]),
        ]);
    }
    println!("{}\n(primed columns are the cost model)\n", t.render());

    // --- Fig. 4 conflict spectrum ------------------------------------------
    println!("== Fig. 4 worst case: consecutive offsets vs spread offsets ==");
    let mut rng = Rng::seeded(7);
    let (n, k) = (1 << 16, 256);
    let mut t = Table::new(vec!["offsets", "conflict degree", "pipeline ms", "2-by-2 ms"]);
    for (label, p) in [
        (
            "consecutive (k..1)",
            SdpProblem::worst_case(n, k, Op::Min, &mut rng),
        ),
        ("random distinct", {
            let offsets = rng.offsets(k, 4 * k as i64);
            let a1 = offsets[0] as usize;
            let init = vec![0i64; a1];
            SdpProblem::new(n, offsets, Op::Min, init).unwrap()
        }),
    ] {
        let pipe = simulator::simulate(&model, &simulator::pipeline_trace(&p));
        let two = simulator::simulate(&model, &simulator::trace::two_by_two_trace(&p));
        t.row(vec![
            label.into(),
            p.longest_consecutive_run().to_string(),
            format!("{:.2}", pipe.ms(&model)),
            format!("{:.2}", two.ms(&model)),
        ]);
    }
    println!("{}\n", t.render());

    // --- §IV-C: MCM steps are O(n²) with n−1 threads ------------------------
    println!("== MCM pipeline step counts vs n² (the §IV-C claim) ==");
    let mut t = Table::new(vec!["n", "cells", "faithful steps", "corrected steps", "steps/n²"]);
    for n in [8usize, 16, 32, 64, 96] {
        let f = McmSchedule::compile(n, McmVariant::PaperFaithful);
        let c = McmSchedule::compile(n, McmVariant::Corrected);
        t.row(vec![
            n.to_string(),
            (n * (n + 1) / 2).to_string(),
            f.num_steps().to_string(),
            c.num_steps().to_string(),
            format!("{:.3}", c.num_steps() as f64 / (n * n) as f64),
        ]);
    }
    println!("{}", t.render());
    println!("\ncorrected ≈ ½·n² steps with ≤ n−1 lanes: the paper's O(n²)-steps");
    println!("claim survives the hazard fix at a small constant-factor cost.");
}
